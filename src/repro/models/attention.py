"""Attention: GQA/MHA/MQA with RoPE, qk-norm, optional biases.

Training/prefill path is a blockwise (flash-style) online-softmax over KV
chunks — pure jnp, so GSPMD can shard it (heads on "model", batch on data
axes, and for decode the KV sequence axis on "model" with the two softmax
reductions turning into all-reduces). Scores/accumulators are f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef, norm_defs, rms_norm, rope

NEG = -1e30


def attn_defs(cfg, cross: bool = False):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    defs = {
        "wq": ParamDef((d, h * dh), ("embed", "heads")),
        "wk": ParamDef((d, kv * dh), ("embed", "kv")),
        "wv": ParamDef((d, kv * dh), ("embed", "kv")),
        "wo": ParamDef((h * dh, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h * dh,), ("heads",), "zeros")
        defs["bk"] = ParamDef((kv * dh,), ("kv",), "zeros")
        defs["bv"] = ParamDef((kv * dh,), ("kv",), "zeros")
    if cfg.qk_norm:
        defs["q_norm"] = norm_defs(dh, "rms")
        defs["k_norm"] = norm_defs(dh, "rms")
    return defs


def _project_qkv(p, x, x_kv, cfg, q_positions, kv_positions):
    from jax.sharding import PartitionSpec as PS
    from ..parallel.sharding import maybe_shard

    B, S, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x_kv, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x_kv, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    # keep heads tensor-parallel through the attention body: without these
    # constraints GSPMD loses the "model" sharding at the GQA reshape and
    # replicates the f32 score tensors (measured 83 GiB/device -> OOM).
    from ..parallel.sharding import ACT_DP
    q = maybe_shard(q, PS(ACT_DP, None, "model"))
    k = maybe_shard(k, PS(ACT_DP, None, "model"))
    v = maybe_shard(v, PS(ACT_DP, None, "model"))
    q = q.reshape(B, S, h, dh)
    k = k.reshape(B, x_kv.shape[1], kv, dh)
    v = v.reshape(B, x_kv.shape[1], kv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"])
        k = rms_norm(k, p["k_norm"]["scale"])
    if cfg.pos == "rope" and q_positions is not None:
        qr, _ = rope(q, q, q_positions, cfg.rope_theta, dh)
        _, kr = rope(k, k, kv_positions, cfg.rope_theta, dh)
        q, k = qr, kr
    return q, k, v


def blockwise_attention(q, k, v, q_pos, k_pos, causal: bool,
                        chunk_k: int = 1024):
    """Online-softmax attention. q: (B,S,H,dh); k/v: (B,T,KV,dh).

    q_pos/k_pos: (S,)/(T,) absolute positions for the causal mask.
    """
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    qf = q.reshape(B, S, KV, g, dh).astype(jnp.float32) * (dh ** -0.5)
    chunk_k = min(chunk_k, T)
    while T % chunk_k:           # largest divisor <= requested chunk
        chunk_k -= 1
    nck = T // chunk_k
    ks = k.reshape(B, nck, chunk_k, KV, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nck, chunk_k, KV, dh).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(nck, chunk_k)

    m0 = jnp.full((B, S, KV, g), NEG, jnp.float32)
    l0 = jnp.zeros((B, S, KV, g), jnp.float32)
    a0 = jnp.zeros((B, S, KV, g, dh), jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        kc, vc, kpc = blk
        s = jnp.einsum("bsKgd,bcKd->bsKgc", qf, kc.astype(jnp.float32))
        if causal:
            mask = (kpc[None, :] <= q_pos[:, None])      # (S, c)
            mask = mask[None, :, None, None, :]          # (1,S,1,1,c)
            s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        if causal:
            p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bsKgc,bcKd->bsKgd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kp))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, dh).astype(q.dtype)


def dense_attention(q, k, v, q_pos, k_pos, causal: bool):
    """Materialized-scores attention (short sequences / training).

    Scores are constrained head-sharded over "model" — the classic
    Megatron-TP layout; under per-block remat the (B,H,S,T) tensors are
    transient, and GSPMD's partitioned softmax needs no while-carry
    sharding inference (which is what breaks the blockwise path's
    backward, see DESIGN.md §Perf notes).
    """
    from jax.sharding import PartitionSpec as PS
    from ..parallel.sharding import ACT_DP, maybe_shard

    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    qf = q.reshape(B, S, KV, g, dh).astype(jnp.float32) * (dh ** -0.5)
    s = jnp.einsum("bsKgd,btKd->bKgst", qf, k.astype(jnp.float32))
    # shard the f32 score tensor over "model": merged (KV*g) head dim when
    # it divides TP (most archs), else the q-sequence dim (arctic's 56
    # heads, whisper's 12)
    from ..parallel.sharding import active_mesh
    mesh = active_mesh()
    tp = dict(zip(mesh.axis_names, mesh.axis_sizes)).get("model", 1) \
        if mesh is not None and not mesh.empty else 1
    if H % max(tp, 1) == 0:
        s = maybe_shard(s.reshape(B, H, S, T),
                        PS(ACT_DP, "model", None, None)).reshape(
                            B, KV, g, S, T)
    else:
        s = maybe_shard(s, PS(ACT_DP, None, None, "model", None))
    if causal:
        mask = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask[None, None, None], s, NEG)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bKgst,btKd->bsKgd", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, dh).astype(q.dtype)


# sequences longer than this use the blockwise online-softmax path
DENSE_MAX_SEQ = 8192


def self_attention(p, x, cfg, positions, causal: bool = True,
                   chunk_k: int | None = None):
    """Full self-attention over x (training / prefill)."""
    q, k, v = _project_qkv(p, x, x, cfg, positions, positions)
    if x.shape[1] <= DENSE_MAX_SEQ:
        out = dense_attention(q, k, v, positions, positions, causal)
    else:
        out = blockwise_attention(q, k, v, positions, positions, causal,
                                  chunk_k or cfg.attn_chunk)
    B, S = x.shape[:2]
    return jnp.einsum("bsh,hd->bsd",
                      out.reshape(B, S, cfg.n_heads * cfg.d_head), p["wo"])


def self_attention_kv(p, x, cfg, positions, causal: bool = True,
                      cache_len: int = 0, chunk_k: int | None = None):
    """self_attention that also returns (k, v) padded to cache_len."""
    q, k, v = _project_qkv(p, x, x, cfg, positions, positions)
    out = blockwise_attention(q, k, v, positions, positions, causal,
                              chunk_k or cfg.attn_chunk)
    B, S = x.shape[:2]
    y = jnp.einsum("bsh,hd->bsd",
                   out.reshape(B, S, cfg.n_heads * cfg.d_head), p["wo"])
    pad = cache_len - S
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return y, (kc, vc)


def cross_attention(p, x, enc, cfg, chunk_k: int | None = None):
    """Decoder->encoder cross attention (no causal mask, no rope)."""
    B, S, _ = x.shape
    T = enc.shape[1]
    q, k, v = _project_qkv(p, x, enc, cfg, None, None)
    pos_q = jnp.arange(S)
    pos_k = jnp.arange(T)
    out = blockwise_attention(q, k, v, pos_q, pos_k, False,
                              min(chunk_k or cfg.attn_chunk, T))
    return jnp.einsum("bsh,hd->bsd",
                      out.reshape(B, S, cfg.n_heads * cfg.d_head), p["wo"])


def decode_self_attention(p, x, cache_k, cache_v, cur_index, cfg):
    """One-token decode against a KV cache.

    x: (B, 1, D); cache_k/v: (B, T, KV, dh) — new K/V written at cur_index.
    Returns (out, new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    T = cache_k.shape[1]
    cur = jnp.asarray(cur_index, jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    pos = jnp.full((1,), cur, jnp.int32)
    q, k, v = _project_qkv(p, x, x, cfg, pos, pos)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (zero, cur, zero, zero))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (zero, cur, zero, zero))

    KV, dh = cfg.n_kv, cfg.d_head
    g = cfg.n_heads // KV
    qf = q.reshape(B, KV, g, dh).astype(jnp.float32) * (dh ** -0.5)
    kf = cache_k.astype(jnp.float32)
    s = jnp.einsum("bKgd,btKd->bKgt", qf, kf)
    mask = jnp.arange(T)[None, None, None, :] <= cur_index
    s = jnp.where(mask, s, NEG)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bKgt,btKd->bKgd", w, cache_v.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.n_heads * dh).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), cache_k, cache_v


def decode_cross_attention(p, x, enc_k, enc_v, cfg):
    """One-token cross attention against precomputed encoder K/V."""
    B = x.shape[0]
    KV, dh = cfg.n_kv, cfg.d_head
    g = cfg.n_heads // KV
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, KV, g, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"])
    qf = q.astype(jnp.float32) * (dh ** -0.5)
    s = jnp.einsum("bKgd,btKd->bKgt", qf, enc_k.astype(jnp.float32))
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bKgt,btKd->bKgd", w, enc_v.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.n_heads * dh).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])

"""Shared model-definition machinery.

Parameters are plain nested dicts of jax arrays. Every parameter is declared
through ``ParamDef`` (shape + logical axes + initializer), which gives us,
from one source of truth:

  - ``init_params``      real initialization (PRNG-split per leaf)
  - ``abstract_params``  ShapeDtypeStruct tree (dry-run: no allocation)
  - ``param_pspecs``     PartitionSpec tree via logical->mesh rules

Logical axes used across the model zoo:
  "embed"   d_model              (sharded over data axes under FSDP)
  "vocab"   vocabulary           (tensor-parallel)
  "heads"   attention heads * head_dim fused   (tensor-parallel)
  "kv"      kv heads * head_dim fused          (tensor-parallel)
  "ff"      mlp hidden           (tensor-parallel)
  "experts" MoE expert axis      (expert-parallel)
  "layers"  stacked scan axis    (never sharded)
  None      replicated
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed | small
    scale: float = 1.0

    def make(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else max(self.shape[-1], 1)
        if self.init == "embed":
            std = 0.02  # GPT-2-style embedding init (tied-head friendly)
        elif self.init == "small":
            std = 0.006
        else:
            std = self.scale / np.sqrt(fan_in)
        return (jax.random.normal(key, self.shape) * std).astype(dtype)


def map_tree(fn: Callable[[ParamDef, Any], Any], defs, *extra):
    """Map over a nested dict of ParamDef leaves."""
    if isinstance(defs, ParamDef):
        return fn(defs, *extra)
    return {k: map_tree(fn, v, *extra) for k, v in defs.items()}


def init_params(defs, key, dtype):
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [d.make(k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(defs, dtype):
    return map_tree(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs)


def param_pspecs(defs, rules: dict[str | None, Any]):
    """Logical axes -> PartitionSpec through the mesh rule table."""
    def one(d: ParamDef):
        return PS(*[rules.get(a, None) for a in d.axes])
    return map_tree(one, defs)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, gain, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gain.astype(jnp.float32)).astype(dt)


def layer_norm(x, gain, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gain.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, params, kind: str):
    if kind == "rms":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


def norm_defs(d_model: int, kind: str):
    if kind == "rms":
        return {"scale": ParamDef((d_model,), ("embed",), "ones")}
    return {"scale": ParamDef((d_model,), ("embed",), "ones"),
            "bias": ParamDef((d_model,), ("embed",), "zeros")}


def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":                   # squared ReLU (Primer / Nemotron-4)
        r = jax.nn.relu(x)
        return r * r
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(kind)


def rope(q, k, positions, theta: float, head_dim: int):
    """Rotary embeddings; q/k: (..., S, H, Dh), positions: (..., S)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]

    def rot(x):
        x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
        out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
        return out.astype(x.dtype)

    return rot(q), rot(k)


def sinusoidal_positions(n_ctx: int, d_model: int):
    pos = np.arange(n_ctx)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d_model)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype=jnp.float32)

"""ModelConfig: one frozen dataclass describing every architecture in the
assigned pool (dense / MoE / hybrid-SSM / attention-free / enc-dec / VLM).

``group`` is the repeating layer pattern, a tuple of (mixer, ffn) pairs:
  mixer in {"attn", "mamba", "rwkv"}
  ffn   in {"mlp", "moe", "moe+mlp" (parallel dense residual, Arctic),
            "rwkv_cm"}
The model is ``n_layers / len(group)`` scan iterations over the stacked
group parameters — heterogeneous stacks (Jamba's 1:7 attn:mamba interleave
with alternating MoE) stay a single compact scan.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                      # 0 -> d_model // n_heads
    arch: str = "decoder"                # decoder | encdec | vlm
    group: tuple = (("attn", "mlp"),)
    act: str = "silu"
    glu: bool = True
    norm: str = "rms"
    qkv_bias: bool = False
    qk_norm: bool = False
    pos: str = "rope"                    # rope | learned | none
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    max_pos: int = 32768                 # learned-pos table size
    # moe
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3
    # mamba
    d_state: int = 16
    d_conv: int = 4
    mamba_expand: int = 2
    # rwkv
    rwkv_head_size: int = 64
    # whisper (enc-dec)
    enc_layers: int = 0
    n_audio_ctx: int = 1500
    # vlm
    n_img_tokens: int = 0
    img_feat_dim: int = 1024
    # numerics / memory knobs
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_chunk: int = 1024
    loss_chunk: int = 512
    remat: str = "full"                  # full | dots | none

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        # pad vocab to a multiple of 256 so the unembedding shards over any
        # power-of-two TP degree (standard practice; only whisper's 51865
        # actually changes — see configs/whisper_small.py)
        object.__setattr__(self, "vocab", -(-self.vocab // 256) * 256)
        assert self.n_layers % len(self.group) == 0, \
            f"{self.name}: n_layers {self.n_layers} % group {len(self.group)}"
        assert self.n_heads % self.n_kv == 0

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.group)

    @property
    def dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    @property
    def is_ssm_only(self) -> bool:
        return all(m != "attn" for m, _ in self.group)

    @property
    def has_attention(self) -> bool:
        return any(m == "attn" for m, _ in self.group)

    @property
    def attn_fraction(self) -> float:
        return sum(m == "attn" for m, _ in self.group) / len(self.group)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid / linear-attention."""
        return self.attn_fraction <= 0.25

    def param_count(self) -> int:
        """Analytic parameter count (sanity-checked against arch names)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        h, kv, dh = self.n_heads, self.n_kv, self.d_head
        total = v * d + (0 if self.tie_embeddings else d * v)
        total += d  # final norm
        fin = 2 * f if self.glu else f

        def attn_p():
            p = d * h * dh + 2 * d * kv * dh + h * dh * d
            if self.qkv_bias:
                p += h * dh + 2 * kv * dh
            return p

        def mlp_p():
            return d * fin + f * d

        def moe_p():
            return d * self.n_experts + self.n_experts * (d * fin + f * d)

        def mamba_p():
            di = self.mamba_expand * d
            return (d * 2 * di + self.d_conv * di + di
                    + di * (self.dt_rank + 2 * self.d_state)
                    + self.dt_rank * di + di + di * self.d_state + di
                    + di * d)

        def rwkv_tm_p():
            return 5 * d * d + d * (5 * 32) + 5 * 32 * d + d * 64 + 64 * d + 9 * d

        def rwkv_cm_p():
            return d * f + f * d + d * d + 2 * d

        for mixer, ffn in self.group:
            total += 2 * d * self.n_groups  # norms
            if mixer == "attn":
                total += attn_p() * self.n_groups
            elif mixer == "mamba":
                total += mamba_p() * self.n_groups
            elif mixer == "rwkv":
                total += rwkv_tm_p() * self.n_groups
            if ffn == "mlp":
                total += mlp_p() * self.n_groups
            elif ffn == "moe":
                total += moe_p() * self.n_groups
            elif ffn == "moe+mlp":
                total += (moe_p() + mlp_p()) * self.n_groups
            elif ffn == "rwkv_cm":
                total += rwkv_cm_p() * self.n_groups
        if self.arch == "encdec":
            # encoder self-attn+mlp stacks + decoder cross-attn
            total += self.enc_layers * (attn_p() + mlp_p() + 4 * d)
            total += self.n_layers * (attn_p() + 2 * d)
        if self.arch == "vlm":
            total += self.img_feat_dim * d + d * d  # 2-layer projector
        if self.pos == "learned":
            total += self.max_pos * d
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts top_k of n_experts."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        d, f = self.d_model, self.d_ff
        fin = 2 * f if self.glu else f
        per_expert = d * fin + f * d
        n_moe_layers = sum(ffn in ("moe", "moe+mlp") for _, ffn in self.group) \
            * self.n_groups
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return full - inactive

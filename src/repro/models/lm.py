"""Model assembly: decoder-only / MoE / hybrid / attention-free / enc-dec /
VLM language models from one block grammar (ModelConfig.group).

Three entry points per model, all pure functions of (params, inputs):

  forward_loss(params, batch, cfg)            training objective
  prefill(params, tokens, cfg, ...)           full-sequence cache build
  decode_step(params, cache, tok, idx, cfg)   one-token serving step

Layers are stacked on a leading "layers" axis and executed with
``lax.scan`` (HLO size independent of depth; remat policy per block).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention as A
from . import mamba as M
from . import moe as F
from . import rwkv6 as R
from .common import (ParamDef, abstract_params, apply_norm, init_params,
                     map_tree, norm_defs, param_pspecs, sinusoidal_positions)
from .config import ModelConfig


# ---------------------------------------------------------------------------
# parameter templates
# ---------------------------------------------------------------------------

def _stack(defs, n: int):
    return map_tree(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init,
                           d.scale), defs)


def _block_defs(cfg: ModelConfig, with_cross: bool):
    d = cfg.d_model
    block: dict[str, Any] = {}
    for i, (mixer, ffn) in enumerate(cfg.group):
        e: dict[str, Any] = {"norm1": norm_defs(d, cfg.norm),
                             "norm2": norm_defs(d, cfg.norm)}
        if mixer == "attn":
            e["attn"] = A.attn_defs(cfg)
        elif mixer == "mamba":
            e["mamba"] = M.mamba_defs(cfg)
        elif mixer == "rwkv":
            e["tm"] = R.rwkv_time_mix_defs(cfg)
        if with_cross:
            e["norm_cross"] = norm_defs(d, cfg.norm)
            e["cross"] = A.attn_defs(cfg)
        if ffn == "mlp":
            e["mlp"] = F.mlp_defs(cfg)
        elif ffn == "moe":
            e["moe"] = F.moe_defs(cfg)
        elif ffn == "moe+mlp":
            e["moe"] = F.moe_defs(cfg)
            e["mlp"] = F.mlp_defs(cfg)
        elif ffn == "rwkv_cm":
            e["cm"] = R.rwkv_channel_mix_defs(cfg)
        block[f"l{i}"] = e
    return block


def model_defs(cfg: ModelConfig):
    d, v = cfg.d_model, cfg.vocab
    defs: dict[str, Any] = {
        "embed": ParamDef((v, d), ("vocab", "embed"), "embed"),
        "final_norm": norm_defs(d, cfg.norm),
        "blocks": _stack(_block_defs(cfg, with_cross=(cfg.arch == "encdec")),
                         cfg.n_groups),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((d, v), ("embed", "vocab"))
    if cfg.pos == "learned":
        defs["pos_embed"] = ParamDef((cfg.max_pos, d), (None, "embed"), "small")
    if cfg.arch == "encdec":
        enc_block = {"l0": {
            "norm1": norm_defs(d, cfg.norm),
            "norm2": norm_defs(d, cfg.norm),
            "attn": A.attn_defs(cfg),
            "mlp": F.mlp_defs(cfg),
        }}
        defs["enc_blocks"] = _stack(enc_block, cfg.enc_layers)
        defs["enc_final_norm"] = norm_defs(d, cfg.norm)
        defs["audio_proj"] = ParamDef((cfg.img_feat_dim, d), (None, "embed"))
    if cfg.arch == "vlm":
        defs["img_proj1"] = ParamDef((cfg.img_feat_dim, d), (None, "embed"))
        defs["img_proj2"] = ParamDef((d, d), ("embed", None))
    return defs


def make_params(cfg: ModelConfig, seed: int = 0):
    return init_params(model_defs(cfg), jax.random.PRNGKey(seed),
                       jnp.dtype(cfg.param_dtype))


def make_abstract_params(cfg: ModelConfig):
    return abstract_params(model_defs(cfg), jnp.dtype(cfg.param_dtype))


def make_param_pspecs(cfg: ModelConfig, rules):
    return param_pspecs(model_defs(cfg), rules)


# ---------------------------------------------------------------------------
# block execution
# ---------------------------------------------------------------------------

def _apply_group(gp, h, cfg: ModelConfig, positions, enc, causal,
                 collect_cache: bool = False, cache_len: int = 0):
    """One repeat of cfg.group. Returns (h, aux, cache_entries)."""
    aux_lb = jnp.zeros((), jnp.float32)
    aux_z = jnp.zeros((), jnp.float32)
    cache: dict[str, Any] = {}
    for i, (mixer, ffn) in enumerate(cfg.group):
        p = gp[f"l{i}"]
        centry: dict[str, Any] = {}
        u = apply_norm(h, p["norm1"], cfg.norm)
        if mixer == "attn":
            if collect_cache:
                out, (k, v) = A.self_attention_kv(p["attn"], u, cfg, positions,
                                                  causal=causal,
                                                  cache_len=cache_len)
                centry["k"], centry["v"] = k, v
            else:
                out = A.self_attention(p["attn"], u, cfg, positions,
                                       causal=causal)
        elif mixer == "mamba":
            if collect_cache:
                out, (conv, hs) = M.mamba_apply_state(p["mamba"], u, cfg)
                centry["conv"], centry["h"] = conv, hs
            else:
                out = M.mamba_apply(p["mamba"], u, cfg)
        elif mixer == "rwkv":
            if collect_cache:
                out, (px, s) = R.rwkv_time_mix_state(p["tm"], u, cfg)
                centry["prev_tm"], centry["s"] = px, s
            else:
                out = R.rwkv_time_mix(p["tm"], u, cfg)
        h = h + out
        if enc is not None:
            c = apply_norm(h, p["norm_cross"], cfg.norm)
            h = h + A.cross_attention(p["cross"], c, enc, cfg)
        u = apply_norm(h, p["norm2"], cfg.norm)
        if ffn == "mlp":
            h = h + F.mlp_apply(p["mlp"], u, cfg)
        elif ffn == "moe":
            y, a = F.moe_apply(p["moe"], u, cfg)
            h = h + y
            aux_lb += a["load_balance"]
            aux_z += a["router_z"]
        elif ffn == "moe+mlp":
            y, a = F.moe_apply(p["moe"], u, cfg)
            h = h + y + F.mlp_apply(p["mlp"], u, cfg)
            aux_lb += a["load_balance"]
            aux_z += a["router_z"]
        elif ffn == "rwkv_cm":
            if collect_cache:
                centry["prev_cm"] = u[:, -1:, :]
            h = h + R.rwkv_channel_mix(p["cm"], u, cfg)
        if centry:
            cache[f"l{i}"] = centry
    return h, (aux_lb, aux_z), cache


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def run_blocks(stacked, h, cfg: ModelConfig, positions, enc=None,
               causal=True, collect_cache=False, cache_len=0):
    """Scan the stacked group params; returns (h, aux, stacked_cache).

    The residual stream is sequence-sharded over "model" between blocks
    (Megatron-style sequence parallelism) whenever a mesh is active and the
    sequence is long enough to split — without this the widest archs cannot
    hold per-layer residuals (DESIGN.md §3).
    """
    from ..parallel.sharding import ACT_DP, maybe_shard
    from jax.sharding import PartitionSpec as PS
    seq_shard = h.shape[1] >= 2048

    def body(carry, gp):
        h, lb, z = carry
        if seq_shard:
            h = maybe_shard(h, PS(ACT_DP, "model", None))
        h, (alb, az), cache = _apply_group(
            gp, h, cfg, positions, enc, causal, collect_cache, cache_len)
        return (h, lb + alb, z + az), cache

    body = _remat(body, cfg.remat)
    z0 = jnp.zeros((), jnp.float32)
    (h, lb, z), cache = jax.lax.scan(body, (h, z0, z0), stacked)
    return h, (lb, z), cache


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg: ModelConfig, offset=0):
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.pos == "learned":
        S = tokens.shape[1]
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], offset, S, 0)
        h = h + pe.astype(cfg.compute_dtype)
    return h


def _unembed_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def chunked_cross_entropy(h, unembed, labels, cfg: ModelConfig):
    """Never materializes (B, S, vocab): scans seq chunks."""
    B, S, D = h.shape
    c = min(cfg.loss_chunk, S)
    while S % c:
        c -= 1
    hs = h.reshape(B, S // c, c, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, S // c, c).transpose(1, 0, 2)

    def body(carry, xc):
        tot, cnt = carry
        hc, lc = xc
        from jax.sharding import PartitionSpec as PS
        from ..parallel.sharding import ACT_DP, maybe_shard
        logits = jnp.einsum("bcd,dv->bcv", hc, unembed).astype(jnp.float32)
        logits = maybe_shard(logits, PS(ACT_DP, None, "model"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = lc >= 0
        tot = (tot + jnp.where(mask, lse - gold, 0.0).sum()
               ).astype(jnp.float32)
        cnt = cnt + mask.sum(dtype=jnp.int32)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hs, ls))
    return tot / jnp.maximum(cnt, 1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------

def _encode_audio(params, audio, cfg: ModelConfig):
    """Whisper encoder over stub frame features (B, n_audio_ctx, feat)."""
    h = jnp.einsum("btf,fd->btd", audio.astype(cfg.compute_dtype),
                   params["audio_proj"].astype(cfg.compute_dtype))
    pe = sinusoidal_positions(cfg.n_audio_ctx, cfg.d_model)
    h = h + pe[None].astype(cfg.compute_dtype)

    def body(carry, gp):
        h, lb, z = carry
        p = gp["l0"]
        u = apply_norm(h, p["norm1"], cfg.norm)
        pos = jnp.arange(cfg.n_audio_ctx)
        h = h + A.self_attention(p["attn"], u, cfg, pos, causal=False)
        u = apply_norm(h, p["norm2"], cfg.norm)
        h = h + F.mlp_apply(p["mlp"], u, cfg)
        return (h, lb, z), None

    body = _remat(body, cfg.remat)
    z0 = jnp.zeros((), jnp.float32)
    (h, _, _), _ = jax.lax.scan(body, (h, z0, z0), params["enc_blocks"])
    return apply_norm(h, params["enc_final_norm"], cfg.norm)


def forward_hidden(params, batch, cfg: ModelConfig):
    """Shared trunk -> final hidden states + aux losses + label mask info."""
    tokens = batch["tokens"]
    enc = None
    if cfg.arch == "encdec":
        enc = _encode_audio(params, batch["audio"], cfg)
        h = _embed(params, tokens, cfg)
        positions = jnp.arange(tokens.shape[1])
    elif cfg.arch == "vlm":
        img = batch["img"].astype(cfg.compute_dtype)
        pre = jnp.einsum("bnf,fd->bnd", img,
                         params["img_proj1"].astype(cfg.compute_dtype))
        pre = jnp.einsum("bnd,de->bne", jax.nn.gelu(pre),
                         params["img_proj2"].astype(cfg.compute_dtype))
        h = jnp.concatenate([pre, _embed(params, tokens, cfg)], axis=1)
        positions = jnp.arange(h.shape[1])
    else:
        h = _embed(params, tokens, cfg)
        positions = jnp.arange(tokens.shape[1])
    h, aux, _ = run_blocks(params["blocks"], h, cfg, positions, enc=enc,
                           causal=True)
    h = apply_norm(h, params["final_norm"], cfg.norm)
    return h, aux


def forward_loss(params, batch, cfg: ModelConfig):
    """Returns (scalar loss, metrics dict). batch["labels"]: -1 = masked."""
    h, (lb, z) = forward_hidden(params, batch, cfg)
    labels = batch["labels"]
    if cfg.arch == "vlm":  # no loss on image prefix positions
        B = labels.shape[0]
        pad = jnp.full((B, cfg.n_img_tokens), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = chunked_cross_entropy(h, _unembed_matrix(params, cfg), labels, cfg)
    loss = ce
    metrics = {"ce": ce}
    if cfg.n_experts:
        loss = loss + cfg.aux_loss_weight * lb + cfg.router_z_weight * z
        metrics["load_balance"] = lb
        metrics["router_z"] = z
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Zeroed decode cache matching the stacked block structure."""
    G = cfg.n_groups
    kv, dh = cfg.n_kv, cfg.d_head
    di = cfg.mamba_expand * cfg.d_model
    H = cfg.d_model // cfg.rwkv_head_size
    dtype = jnp.dtype(cfg.compute_dtype)
    cache: dict[str, Any] = {}
    for i, (mixer, ffn) in enumerate(cfg.group):
        e: dict[str, Any] = {}
        if mixer == "attn":
            e["k"] = jnp.zeros((G, batch, max_len, kv, dh), dtype)
            e["v"] = jnp.zeros((G, batch, max_len, kv, dh), dtype)
        elif mixer == "mamba":
            e["conv"] = jnp.zeros((G, batch, cfg.d_conv - 1, di), dtype)
            e["h"] = jnp.zeros((G, batch, di, cfg.d_state), jnp.float32)
        elif mixer == "rwkv":
            e["prev_tm"] = jnp.zeros((G, batch, 1, cfg.d_model), dtype)
            e["s"] = jnp.zeros((G, batch, H, cfg.rwkv_head_size,
                                cfg.rwkv_head_size), jnp.float32)
        if ffn == "rwkv_cm":
            e["prev_cm"] = jnp.zeros((G, batch, 1, cfg.d_model), dtype)
        if cfg.arch == "encdec":
            e["ck"] = jnp.zeros((G, batch, cfg.n_audio_ctx, kv, dh), dtype)
            e["cv"] = jnp.zeros((G, batch, cfg.n_audio_ctx, kv, dh), dtype)
        cache[f"l{i}"] = e
    return cache


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    """Process a full prompt; returns (cache, logits_last)."""
    tokens = batch["tokens"]
    enc = _encode_audio(params, batch["audio"], cfg) \
        if cfg.arch == "encdec" else None
    if cfg.arch == "vlm":
        img = batch["img"].astype(cfg.compute_dtype)
        pre = jnp.einsum("bnf,fd->bnd", img,
                         params["img_proj1"].astype(cfg.compute_dtype))
        pre = jnp.einsum("bnd,de->bne", jax.nn.gelu(pre),
                         params["img_proj2"].astype(cfg.compute_dtype))
        h = jnp.concatenate([pre, _embed(params, tokens, cfg)], axis=1)
    else:
        h = _embed(params, tokens, cfg)
    positions = jnp.arange(h.shape[1])
    h, _, cache = run_blocks(params["blocks"], h, cfg, positions, enc=enc,
                             causal=True, collect_cache=True,
                             cache_len=max_len)
    if cfg.arch == "encdec":
        cache = _add_cross_cache(params, cache, enc, cfg)
    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = jnp.einsum("bd,dv->bv", h[:, -1, :],
                        _unembed_matrix(params, cfg)).astype(jnp.float32)
    return cache, logits


def _add_cross_cache(params, cache, enc, cfg: ModelConfig):
    kv, dh = cfg.n_kv, cfg.d_head
    B, T = enc.shape[0], enc.shape[1]

    def per_group(gp, centry):
        for i in range(len(cfg.group)):
            p = gp[f"l{i}"]["cross"]
            k = jnp.einsum("btd,dh->bth", enc, p["wk"])
            v = jnp.einsum("btd,dh->bth", enc, p["wv"])
            if cfg.qkv_bias:
                k = k + p["bk"]
                v = v + p["bv"]
            centry[f"l{i}"]["ck"] = k.reshape(B, T, kv, dh)
            centry[f"l{i}"]["cv"] = v.reshape(B, T, kv, dh)
        return centry

    def body(_, x):
        gp, ce = x
        return None, per_group(gp, ce)

    _, cache = jax.lax.scan(body, None, (params["blocks"], cache))
    return cache


def decode_step(params, cache, tokens, cur_index, cfg: ModelConfig):
    """One-token decode. tokens: (B, 1); cur_index: scalar int32.

    Returns (logits (B, vocab) f32, updated cache).
    """
    h = _embed(params, tokens, cfg, offset=cur_index) \
        if cfg.pos == "learned" else _embed(params, tokens, cfg)

    def body(h, xs):
        gp, gc = xs
        newc = {}
        for i, (mixer, ffn) in enumerate(cfg.group):
            p = gp[f"l{i}"]
            c = gc[f"l{i}"]
            e = {}
            u = apply_norm(h, p["norm1"], cfg.norm)
            if mixer == "attn":
                out, k, v = A.decode_self_attention(
                    p["attn"], u, c["k"], c["v"], cur_index, cfg)
                e["k"], e["v"] = k, v
            elif mixer == "mamba":
                out, conv, hs = M.mamba_decode_step(
                    p["mamba"], u, c["conv"], c["h"], cfg)
                e["conv"], e["h"] = conv, hs
            elif mixer == "rwkv":
                out, px, s = R.rwkv_time_mix_step(
                    p["tm"], u, c["prev_tm"], c["s"], cfg)
                e["prev_tm"], e["s"] = px, s
            h = h + out
            if cfg.arch == "encdec":
                cx = apply_norm(h, p["norm_cross"], cfg.norm)
                h = h + A.decode_cross_attention(p["cross"], cx, c["ck"],
                                                 c["cv"], cfg)
                e["ck"], e["cv"] = c["ck"], c["cv"]
            u = apply_norm(h, p["norm2"], cfg.norm)
            if ffn == "mlp":
                h = h + F.mlp_apply(p["mlp"], u, cfg)
            elif ffn in ("moe", "moe+mlp"):
                y, _ = F.moe_apply(p["moe"], u, cfg)
                h = h + y
                if ffn == "moe+mlp":
                    h = h + F.mlp_apply(p["mlp"], u, cfg)
            elif ffn == "rwkv_cm":
                out, pcm = R.rwkv_channel_mix_step(p["cm"], u, c["prev_cm"],
                                                   cfg)
                h = h + out
                e["prev_cm"] = pcm
            newc[f"l{i}"] = e
        return h, newc

    h, cache = jax.lax.scan(body, h, (params["blocks"], cache))
    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = jnp.einsum("bd,dv->bv", h[:, -1, :],
                        _unembed_matrix(params, cfg)).astype(jnp.float32)
    return logits, cache

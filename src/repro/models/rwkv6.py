"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free mixer with
data-dependent per-channel decay.

Time-mix: token-shift with LoRA-interpolated lerp coefficients, decay
w_t = exp(-exp(w0 + lora(x))) per channel, WKV matrix-state recurrence
per head (state (dh, dh)), bonus u on the diagonal step, grouped
head-norm, silu gate. Channel-mix: token-shift + squared-relu MLP with
sigmoid receptance. Serial `lax.scan` over time for training (compact
HLO, exact); O(1)-state decode step for serving — this is why rwkv6 runs
the long_500k shape that dense-attention archs skip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef


LORA_TM = 32      # token-mix lora rank
LORA_DECAY = 64   # decay lora rank


def rwkv_time_mix_defs(cfg):
    c = cfg.d_model
    return {
        "maa_x": ParamDef((c,), ("embed",), "zeros"),
        "maa": ParamDef((5, c), (None, "embed"), "zeros"),   # w,k,v,r,g
        "tm_w1": ParamDef((c, 5 * LORA_TM), ("embed", None), "small"),
        "tm_w2": ParamDef((5, LORA_TM, c), (None, None, "embed"), "small"),
        "w0": ParamDef((c,), ("embed",), "zeros"),
        "td_w1": ParamDef((c, LORA_DECAY), ("embed", None), "small"),
        "td_w2": ParamDef((LORA_DECAY, c), (None, "embed"), "small"),
        "u": ParamDef((c,), ("embed",), "zeros"),
        "wr": ParamDef((c, c), ("embed", "heads")),
        "wk": ParamDef((c, c), ("embed", "heads")),
        "wv": ParamDef((c, c), ("embed", "heads")),
        "wg": ParamDef((c, c), ("embed", "heads")),
        "wo": ParamDef((c, c), ("heads", "embed")),
        "ln_x_scale": ParamDef((c,), ("embed",), "ones"),
        "ln_x_bias": ParamDef((c,), ("embed",), "zeros"),
    }


def rwkv_channel_mix_defs(cfg):
    c, f = cfg.d_model, cfg.d_ff
    return {
        "maa_k": ParamDef((c,), ("embed",), "zeros"),
        "maa_r": ParamDef((c,), ("embed",), "zeros"),
        "wk": ParamDef((c, f), ("embed", "ff")),
        "wv": ParamDef((f, c), ("ff", "embed")),
        "wr": ParamDef((c, c), ("embed", None)),
    }


def _shift(x, prev=None):
    """Token shift: x_{t-1} (zero/`prev` at t=0). x: (B,S,C)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _tm_inputs(p, x, cfg, prev=None):
    xx = _shift(x, prev) - x
    xxx = x + xx * p["maa_x"]
    m = jnp.tanh(jnp.einsum("bsc,cr->bsr", xxx, p["tm_w1"]))
    m = m.reshape(*m.shape[:-1], 5, LORA_TM)
    m = jnp.einsum("bsfr,frc->bsfc", m, p["tm_w2"])       # (B,S,5,C)
    lerp = p["maa"][None, None] + m
    xw, xk, xv, xr, xg = [x + xx * lerp[:, :, i] for i in range(5)]

    H = cfg.d_model // cfg.rwkv_head_size
    dh = cfg.rwkv_head_size

    def heads(v):
        return v.reshape(*v.shape[:-1], H, dh)

    r = heads(jnp.einsum("bsc,ch->bsh", xr, p["wr"]))
    k = heads(jnp.einsum("bsc,ch->bsh", xk, p["wk"]))
    v = heads(jnp.einsum("bsc,ch->bsh", xv, p["wv"]))
    g = jnp.einsum("bsc,ch->bsh", xg, p["wg"])
    dec = jnp.exp(-jnp.exp(
        p["w0"].astype(jnp.float32)
        + jnp.einsum("bsc,cr->bsr", jnp.tanh(
            jnp.einsum("bsc,cd->bsd", xw, p["td_w1"])), p["td_w2"])
        .astype(jnp.float32)))
    return r, k, v, g, heads(dec), heads(p["u"][None, None])


def _out_norm(p, wkv, g, cfg, B, S):
    """Per-head group norm + gate + out projection. wkv: (B,S,H,dh)."""
    x32 = wkv.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = ((x32 - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(B, S, cfg.d_model)
    y = y * p["ln_x_scale"] + p["ln_x_bias"]
    y = y.astype(wkv.dtype) * jax.nn.silu(g)
    return jnp.einsum("bsc,cd->bsd", y, p["wo"])


def _time_mix_core(p, x, cfg):
    B, S, C = x.shape
    r, k, v, g, w, u = _tm_inputs(p, x, cfg)
    H, dh = C // cfg.rwkv_head_size, cfg.rwkv_head_size

    def step(state, inp):
        rt, kt, vt, wt = inp                              # (B,H,dh) each
        kv = kt.astype(jnp.float32)[..., None] * vt.astype(jnp.float32)[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj",
                       rt.astype(jnp.float32),
                       state + u.astype(jnp.float32)[0, 0, :, :, None] * kv)
        state = state * wt.astype(jnp.float32)[..., None] + kv
        return state, y

    s0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    s_final, ys = jax.lax.scan(step, s0, xs)
    wkv = ys.transpose(1, 0, 2, 3).astype(x.dtype)        # (B,S,H,dh)
    return _out_norm(p, wkv, g, cfg, B, S), s_final


def rwkv_time_mix(p, x, cfg):
    """Training path; x: (B,S,C)."""
    return _time_mix_core(p, x, cfg)[0]


def rwkv_time_mix_state(p, x, cfg):
    """Prefill variant: also returns (prev_x, state) for decoding."""
    out, s_final = _time_mix_core(p, x, cfg)
    return out, (x[:, -1:, :], s_final)


def rwkv_time_mix_step(p, x, prev_x, state, cfg):
    """Decode step. x: (B,1,C); state: (B,H,dh,dh) f32."""
    B, _, C = x.shape
    r, k, v, g, w, u = _tm_inputs(p, x, cfg, prev=prev_x)
    rt, kt, vt, wt = (a[:, 0] for a in (r, k, v, w))
    kv = kt.astype(jnp.float32)[..., None] * vt.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", rt.astype(jnp.float32),
                   state + u.astype(jnp.float32)[0, 0, :, :, None] * kv)
    state = state * wt.astype(jnp.float32)[..., None] + kv
    wkv = y[:, None].reshape(B, 1, C // cfg.rwkv_head_size, cfg.rwkv_head_size)
    out = _out_norm(p, wkv.astype(x.dtype), g, cfg, B, 1)
    return out, x[:, 0:1], state


def rwkv_channel_mix(p, x, cfg, prev=None):
    xx = _shift(x, prev) - x
    xk = x + xx * p["maa_k"]
    xr = x + xx * p["maa_r"]
    h = jnp.einsum("bsc,cf->bsf", xk, p["wk"])
    h = jnp.square(jax.nn.relu(h))
    kv = jnp.einsum("bsf,fc->bsc", h, p["wv"])
    return jax.nn.sigmoid(jnp.einsum("bsc,cd->bsd", xr, p["wr"])) * kv


def rwkv_channel_mix_step(p, x, prev_x, cfg):
    out = rwkv_channel_mix(p, x, cfg, prev=prev_x)
    return out, x[:, 0:1]

"""Mamba (selective SSM) block — the Jamba hybrid's mixer.

Faithful mamba-1 structure: in-proj -> causal depthwise conv -> selective
(input-dependent) dt/B/C -> diagonal state-space scan -> gated out-proj,
with Jamba's dt/B/C RMS norms.

The training scan is a `lax.scan` over time computing the per-step
(B, d_inner, d_state) update in-register — nothing of size S x d_inner x
d_state is ever materialized (that tensor would be TBs for Jamba). A
chunked/parallel formulation is a known further optimization (see
EXPERIMENTS.md §Perf); the serial scan keeps HLO compact and exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef, rms_norm


def mamba_defs(cfg):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.d_state
    dtr = cfg.dt_rank
    return {
        "in_proj": ParamDef((d, 2 * di), ("embed", "ff")),
        "conv_w": ParamDef((cfg.d_conv, di), (None, "ff")),
        "conv_b": ParamDef((di,), ("ff",), "zeros"),
        "x_proj": ParamDef((di, dtr + 2 * n), ("ff", None)),
        "dt_w": ParamDef((dtr, di), (None, "ff")),
        "dt_b": ParamDef((di,), ("ff",), "zeros"),
        "A_log": ParamDef((di, n), ("ff", None), "ones"),
        "D": ParamDef((di,), ("ff",), "ones"),
        "out_proj": ParamDef((di, d), ("ff", "embed")),
        "dt_norm": ParamDef((dtr,), (None,), "ones"),
        "b_norm": ParamDef((n,), (None,), "ones"),
        "c_norm": ParamDef((n,), (None,), "ones"),
    }


def _conv_causal(x, w, b):
    """Depthwise causal conv; x: (B,S,di), w: (K,di)."""
    K, di = w.shape
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :], window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=di)
    return out + b


def _ssm_inputs(p, x, cfg):
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_conv_causal(xin, p["conv_w"], p["conv_b"]))
    proj = jnp.einsum("bse,ef->bsf", xc, p["x_proj"])
    dtr, n = cfg.dt_rank, cfg.d_state
    dt_in, bb, cc = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt_in = rms_norm(dt_in, p["dt_norm"])
    bb = rms_norm(bb, p["b_norm"])
    cc = rms_norm(cc, p["c_norm"])
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt_in, p["dt_w"]) + p["dt_b"])
    return xc, z, dt, bb, cc


def _mamba_core(p, x, cfg):
    B, S, D = x.shape
    xc, z, dt, bb, cc = _ssm_inputs(p, x, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (di, n)

    def step(h, inp):
        xct, dtt, bt, ct = inp                            # (B,di),(B,di),(B,n),(B,n)
        dA = jnp.exp(dtt.astype(jnp.float32)[..., None] * A)      # (B,di,n)
        dBx = (dtt * xct).astype(jnp.float32)[..., None] * bt.astype(jnp.float32)[:, None, :]
        h = h * dA + dBx
        y = jnp.einsum("ben,bn->be", h, ct.astype(jnp.float32))
        return h, y.astype(x.dtype)

    h0 = jnp.zeros((B, cfg.mamba_expand * D, cfg.d_state), jnp.float32)
    xs = (xc.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          bb.transpose(1, 0, 2), cc.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2)                             # (B,S,di)
    y = y + xc * p["D"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, h_final


def mamba_apply(p, x, cfg):
    """x: (B, S, D) -> (B, S, D)."""
    return _mamba_core(p, x, cfg)[0]


def mamba_apply_state(p, x, cfg):
    """Prefill variant: also returns (conv_tail, h_final) decode state."""
    out, h_final = _mamba_core(p, x, cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, _ = jnp.split(xz, 2, axis=-1)
    conv_tail = xin[:, -(cfg.d_conv - 1):, :]
    return out, (conv_tail, h_final)


def mamba_decode_step(p, x, conv_state, h, cfg):
    """One-token decode. x: (B,1,D); conv_state: (B, K-1, di); h: (B,di,n)."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)                    # (B,1,di)
    window = jnp.concatenate([conv_state, xin[:, 0:1, :]], axis=1)  # (B,K,di)
    xc = jax.nn.silu((window * p["conv_w"][None]).sum(axis=1, keepdims=True)
                     + p["conv_b"])
    proj = jnp.einsum("bse,ef->bsf", xc, p["x_proj"])
    dtr, n = cfg.dt_rank, cfg.d_state
    dt_in, bb, cc = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt_in = rms_norm(dt_in, p["dt_norm"])
    bb = rms_norm(bb, p["b_norm"])
    cc = rms_norm(cc, p["c_norm"])
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt_in, p["dt_w"]) + p["dt_b"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[:, 0].astype(jnp.float32)[..., None] * A)
    dBx = (dt[:, 0] * xc[:, 0]).astype(jnp.float32)[..., None] \
        * bb[:, 0].astype(jnp.float32)[:, None, :]
    h = h * dA + dBx
    y = jnp.einsum("ben,bn->be", h, cc[:, 0].astype(jnp.float32))[:, None, :]
    y = y.astype(x.dtype) + xc * p["D"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, window[:, 1:, :], h

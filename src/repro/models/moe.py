"""Mixture-of-experts FFN: top-k softmax router + sort-based capacity
dispatch (GShard/Switch style, dropped-token on overflow).

Expert weights carry the "experts" logical axis -> sharded over the
"model" mesh axis (expert parallelism). The (E, C, D) dispatch buffer gets
an explicit sharding constraint on its expert axis so GSPMD materializes
the token all-to-all instead of an all-gather.

Aux outputs: load-balance loss (Switch eq. (4)) + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from ..parallel.sharding import ACT_DP, maybe_shard
from .common import ParamDef, activation


def moe_defs(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    fin = 2 * f if cfg.glu else f
    return {
        "router": ParamDef((d, e), ("embed", None), scale=0.1),
        "w_in": ParamDef((e, d, fin), ("experts", "embed", None)),
        "w_out": ParamDef((e, f, d), ("experts", None, "embed")),
    }


def _capacity(tokens: int, cfg) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def moe_apply(p, x, cfg, ep_axes=("model",)):
    """x: (B, S, D) -> (y, aux) with aux = {load_balance, router_z}."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = _capacity(T, cfg)
    # token-major tensors stay sharded over the data axes end to end; the
    # only resharding is the token->expert all-to-all at the dispatch
    # buffer (EP constraint below). Without these constraints GSPMD
    # replicates the data-dependent scatter/gather operands (measured
    # 285 GiB/device on arctic-480b train, see EXPERIMENTS.md §Perf).
    xf = maybe_shard(x.reshape(T, D), PS(ACT_DP, None))

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    logits = maybe_shard(logits, PS(ACT_DP, None))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)              # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- sort token-slots by destination expert ----------------------------
    # gather-only dispatch: both the (E, C, D) expert buffer and the
    # (T, K, D) combine are gathers through index tables derived from one
    # argsort — no data-sized scatter anywhere (GSPMD replicates scattered
    # operands with data-dependent indices; measured on arctic-480b).
    flat_e = expert.reshape(-1)                          # (T*K,)
    order = jnp.argsort(flat_e)                          # stable
    sorted_e = flat_e[order]
    ones = jnp.ones_like(sorted_e)
    counts = jax.ops.segment_sum(ones, sorted_e, num_segments=E)
    offsets = jnp.cumsum(counts) - counts

    slot_q = offsets[:, None] + jnp.arange(C)[None, :]   # (E, C) sorted pos
    slot_valid = jnp.arange(C)[None, :] < counts[:, None]
    slot_flat = jnp.where(slot_valid,
                          order[jnp.clip(slot_q, 0, T * K - 1)], 0)
    src_token = slot_flat // K                           # (E, C)
    buf = jnp.where(slot_valid[..., None], xf[src_token], 0)
    buf = maybe_shard(buf, PS(ep_axes, None, None))      # token->expert a2a

    # ---- expert computation (grouped GEMM on the MXU) ----------------------
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    if cfg.glu:
        u, g = jnp.split(h, 2, axis=-1)
        h = u * activation(g, cfg.act)
    else:
        h = activation(h, cfg.act)
    out = jnp.einsum("ecf,efd->ecd", h, p["w_out"]).reshape(E * C, D)

    # ---- combine (gather back through the inverse permutation) -------------
    inv = jnp.argsort(order)                             # (T*K,)
    c_tk = inv - offsets[flat_e]                         # position in expert
    keep = c_tk < C
    back = flat_e * C + jnp.minimum(c_tk, C - 1)
    slot_out = jnp.where(keep[:, None], out[back], 0.0)  # (T*K, D)
    slot_out = maybe_shard(slot_out, PS(ACT_DP, None))   # expert->token a2a
    y = (slot_out.reshape(T, K, D)
         * gate[..., None].astype(x.dtype)).sum(axis=1).reshape(B, S, D)

    # ---- aux losses ---------------------------------------------------------
    me = probs.mean(axis=0)                              # mean router prob
    ce = jax.ops.segment_sum(jnp.ones_like(flat_e), flat_e,
                             num_segments=E) / (T * K)   # token fraction
    aux = {
        "load_balance": E * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return y, aux


def mlp_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    fin = 2 * f if cfg.glu else f
    return {
        "w_in": ParamDef((d, fin), ("embed", "ff")),
        "w_out": ParamDef((f, d), ("ff", "embed")),
    }


def mlp_apply(p, x, cfg):
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if cfg.glu:
        u, g = jnp.split(h, 2, axis=-1)
        h = u * activation(g, cfg.act)
    else:
        h = activation(h, cfg.act)
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])

"""Model zoo substrate: one block grammar covering dense / MoE / hybrid /
attention-free / enc-dec / VLM architectures (see configs/)."""
from .config import ModelConfig
from .lm import (decode_step, forward_hidden, forward_loss, init_cache,
                 make_abstract_params, make_param_pspecs, make_params,
                 model_defs, prefill)

__all__ = [
    "ModelConfig", "model_defs", "make_params", "make_abstract_params",
    "make_param_pspecs", "forward_loss", "forward_hidden", "prefill",
    "decode_step", "init_cache",
]

"""Fault-tolerant checkpointing.

Design (multi-thousand-node story, single-host mechanics here):

  * atomic: state is written to ``<dir>/tmp-<step>`` and ``os.replace``d to
    ``<dir>/step_<n>`` only after every leaf + manifest hit disk — a crash
    mid-write can never corrupt the restore set;
  * async: ``CheckpointManager.save`` snapshots device arrays to host then
    hands the disk I/O to a background thread (training continues; next
    save waits on the previous one — orbax-style);
  * elastic: leaves are stored as *full logical arrays* plus the logical
    PartitionSpec metadata. Restore takes the *current* mesh's shardings
    and ``jax.device_put``s each leaf — the same checkpoint restores onto
    any DP width (scale up/down after node loss);
  * self-describing: manifest.json carries step, tree structure, shapes,
    dtypes and integrity (per-leaf byte sizes).

In a real multi-host deployment each host would write only its addressable
shards (same manifest format, shard index per file); the reader below
already reconstructs from per-leaf files, so that extension is local to
``_write_leaf``/``_read_leaf``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import numpy as np
import jax


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict[str, Any]):
    if set(flat) == {""}:          # bare-leaf tree
        return flat[""]
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    """Atomic, synchronous save. Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp-{step}-{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "time": time.time(),
                "format": 1}
    for key, val in flat.items():
        arr = np.asarray(jax.device_get(val))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "bytes": int(arr.nbytes),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int | None = None,
                       shardings=None):
    """Load a checkpoint; optionally re-shard every leaf onto the current
    mesh (``shardings``: pytree of jax.sharding.Sharding matching the saved
    tree — the *elastic* path: mesh may differ from save time)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for key, meta in manifest["leaves"].items():
        path = os.path.join(d, meta["file"])
        if os.path.getsize(path) < meta["bytes"]:
            raise IOError(f"corrupt checkpoint leaf {key}")
        flat[key] = np.load(path)
    tree = _unflatten(flat)
    if shardings is not None:
        flat_s = _flatten(shardings)
        flat_t = _flatten(tree)
        tree = _unflatten({
            k: jax.device_put(flat_t[k], flat_s[k]) if k in flat_s
            else flat_t[k]
            for k in flat_t
        })
    return tree, step


class CheckpointManager:
    """Async saves + retention + restore-latest."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree, blocking: bool = False):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.dir, step, host_tree)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, shardings=None):
        self.wait()
        return restore_checkpoint(self.dir, None, shardings)

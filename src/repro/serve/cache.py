"""Keyed executable cache for the serving plane.

``FmmSolver.build`` already memoizes compiled solvers per
``(FmmConfig, backend)`` in a bounded LRU. Serving adds two more key
axes that change the compiled program: the **bucket** (padded problem
size — ``FmmConfig.n`` is a static shape) and the **batch width** B
(``apply_batched`` compiles per (B, N)). This module extends the solver
LRU upward into a ``(config, bucket, batch, backend)``-keyed cache of
*guarded* executables:

  - each entry is a ``GuardedSolver`` pinned to one (bucket, B) shape
    class — it persists across requests, so cap escalations learned
    from traffic (guard promotion) stick to the shape class;
  - ``warm`` precompiles an entry ahead of traffic (the batched health
    twin — the program every guarded dispatch runs);
  - eviction is LRU with per-bucket hit/miss/eviction counters
    (``info``), the serving analogue of ``FmmSolver.cache_info()``; an
    evicted entry's underlying compiled programs are released when the
    solver-level LRU drops them (``FmmSolver`` eviction now clears its
    jitted entry points, health twins included).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, NamedTuple, Optional

import jax
import numpy as np

from ..core.config import FmmConfig
from ..solver.guard import GuardedSolver


class BucketCacheStats(NamedTuple):
    """Per-bucket hit/miss/eviction counters of the serving cache."""

    hits: int
    misses: int
    evictions: int


def default_cfg_factory(n: int, *, p: int = 17, dtype: str = "f32",
                        strong_cap: int = 48,
                        weak_cap: int = 128) -> FmmConfig:
    """Bucket size -> ``FmmConfig`` (paper calibration: eq. (5.2) depth)."""
    from ..configs.fmm2d import fmm_config

    cfg = fmm_config(n, p=p, dtype=dtype)
    return dataclasses.replace(cfg, strong_cap=strong_cap,
                               weak_cap=weak_cap)


class PlanCache:
    """LRU of guarded executables keyed by (bucket, batch, backend).

    ``get`` returns ``(guarded_solver, hit)``; ``warm`` precompiles the
    entry's batched health twin on synthetic data so the first real
    request pays a cache hit, not a compile.
    """

    def __init__(self, cfg_factory: Callable[[int], FmmConfig],
                 backend: str = "auto", *, max_entries: int = 16,
                 max_cap_doublings: int = 3):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.cfg_factory = cfg_factory
        self.backend = backend
        self.max_entries = max_entries
        self.max_cap_doublings = max_cap_doublings
        self._entries: OrderedDict[tuple, GuardedSolver] = OrderedDict()
        self._stats: dict[int, dict] = {}

    # -- bookkeeping --------------------------------------------------------

    def _bucket_stats(self, bucket: int) -> dict:
        return self._stats.setdefault(
            bucket, {"hits": 0, "misses": 0, "evictions": 0})

    def info(self) -> dict[int, BucketCacheStats]:
        """Per-bucket counters (plus ``currsize``/``maxsize`` totals via
        ``len(cache)`` and ``cache.max_entries``)."""
        return {b: BucketCacheStats(s["hits"], s["misses"], s["evictions"])
                for b, s in sorted(self._stats.items())}

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()
        self._stats.clear()

    # -- the executable cache ----------------------------------------------

    def get(self, bucket: int, batch: int) -> tuple[GuardedSolver, bool]:
        """The guarded executable of one (bucket, batch) shape class.

        A hit returns the *same* ``GuardedSolver`` instance — including
        any cap escalation its guard promoted from earlier traffic."""
        key = (bucket, batch, self.backend)
        stats = self._bucket_stats(bucket)
        entry = self._entries.get(key)
        if entry is not None:
            stats["hits"] += 1
            self._entries.move_to_end(key)
            return entry, True
        stats["misses"] += 1
        entry = GuardedSolver(self.cfg_factory(bucket), self.backend,
                              max_cap_doublings=self.max_cap_doublings)
        self._entries[key] = entry
        while len(self._entries) > self.max_entries:
            (ev_bucket, _, _), _ = self._entries.popitem(last=False)
            self._bucket_stats(ev_bucket)["evictions"] += 1
        return entry, False

    def warm(self, bucket: int, batch: int,
             seed: int = 0) -> GuardedSolver:
        """Precompile one shape class ahead of traffic: trace + compile
        the batched health twin (the program guarded dispatch runs) on
        synthetic particles. Idempotent; returns the cached entry."""
        from ..data.synthetic import particles

        guarded, _ = self.get(bucket, batch)
        cfg = guarded.cfg
        z, q = particles("uniform", bucket, seed)
        zb = np.broadcast_to(np.asarray(z, dtype=cfg.complex_dtype),
                             (batch, bucket))
        qb = np.broadcast_to(np.asarray(q, dtype=cfg.complex_dtype),
                             (batch, bucket))
        solver = guarded.solver
        jax.block_until_ready(
            solver.apply_batched_with_health(jax.numpy.asarray(zb),
                                             jax.numpy.asarray(qb))[0])
        return guarded

    def warm_all(self, buckets, batches) -> list[tuple[int, int]]:
        """Warm the cross product ``buckets`` x ``batches``; returns the
        warmed (bucket, batch) pairs in order."""
        warmed = []
        for b in buckets:
            for w in batches:
                self.warm(b, w)
                warmed.append((b, w))
        return warmed

    def entry(self, bucket: int, batch: int) -> Optional[GuardedSolver]:
        """Peek without touching LRU order or counters (tests)."""
        return self._entries.get((bucket, batch, self.backend))

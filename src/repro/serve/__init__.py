"""Ragged-traffic serving plane (DESIGN.md §10).

Routes heterogeneous (z, q) request streams onto the compiled batched
pipeline: shape bucketing with exact zero-charge padding, a keyed
guarded-executable cache with warm-up and per-bucket counters, and an
admission + degradation controller that turns every fault into either a
recovery or a typed rejection in a structured ``ServeReport``.
"""
from .buckets import BucketLattice, pad_problem, unpad
from .cache import BucketCacheStats, PlanCache, default_cfg_factory
from .plane import (Request, ServePlane, ServeReport, ServeResult,
                    STATUSES)

__all__ = [
    "BucketLattice",
    "pad_problem",
    "unpad",
    "BucketCacheStats",
    "PlanCache",
    "default_cfg_factory",
    "Request",
    "ServePlane",
    "ServeReport",
    "ServeResult",
    "STATUSES",
]

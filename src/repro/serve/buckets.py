"""Shape bucketing: ragged N onto a small lattice of padded shape classes.

Every compiled FMM program is specialized on ``FmmConfig.n`` (the
static-shape property the paper's padded interaction lists buy us), so
heterogeneous traffic would naively compile one executable per distinct
request size — a compile storm. The serving plane instead rounds each
request up to the nearest size in a small geometric ``BucketLattice``
and pads the tail with **zero-charge particles**, which is *mathematically
exact* for the real rows:

  - every expansion coefficient is a q-weighted sum, so a q=0 particle
    contributes exactly nothing to P2M/P2L/M2L/L2P;
  - the near-field P2P term of a q=0 source is 0/r = 0 for any target it
    doesn't coincide with — and padding positions are drawn *rejected
    against exact coincidence* with the real points (and each other), so
    the 0/0 singular case cannot occur (coincidence with a q=0 source
    would make the harmonic P2P term NaN);
  - padded rows receive garbage potentials, which ``unpad`` slices away.

What padding *does* change is the tree: the rank-median splits see the
extra particles, so box geometry shifts and the result differs from the
unpadded evaluation by the p-term truncation error only — the
bucket-boundary parity tests pin this at <= 1e-10 (f64, p=30), and the
tail-masking property (zero charges in, zeros out) holds at any p.

Padding positions are drawn inside the bounding box of the real points
(deterministic in (seed, size, n)), so the root box and the particle
density the caps were tuned for barely move; a degenerate bounding box
(all-coincident or collinear input) is widened by a relative epsilon so
rejection sampling terminates.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..errors import ShapeError


@dataclasses.dataclass(frozen=True)
class BucketLattice:
    """Ascending tuple of padded problem sizes (shape classes).

    ``bucket_for(n)`` rounds a request up to its shape class;
    ``None`` means the request is oversized for the lattice and must
    take the degradation ladder (direct O(N^2) for small N, typed
    rejection otherwise — see ``repro.serve.plane``).
    """

    sizes: tuple[int, ...]

    def __post_init__(self):
        if not self.sizes:
            raise ValueError("BucketLattice needs at least one size")
        if list(self.sizes) != sorted(set(self.sizes)):
            raise ValueError(f"sizes must be strictly ascending: {self.sizes}")
        if self.sizes[0] < 4:
            raise ValueError("smallest bucket must be >= 4")

    @classmethod
    def geometric(cls, n_min: int = 64, n_max: int = 1 << 16,
                  factor: float = 2.0) -> "BucketLattice":
        """Geometric lattice from ``n_min`` up to (at least) ``n_max``.

        A factor-F lattice wastes at most (F-1)x padding per request and
        needs only log_F(n_max/n_min) compiled shape classes — the
        standard padding/compile-count trade (factor 2 by default).
        """
        if factor <= 1.0:
            raise ValueError("factor must be > 1")
        sizes = [n_min]
        while sizes[-1] < n_max:
            sizes.append(max(sizes[-1] + 1,
                             int(math.ceil(sizes[-1] * factor))))
        return cls(sizes=tuple(sizes))

    @property
    def max_size(self) -> int:
        return self.sizes[-1]

    def bucket_for(self, n: int) -> int | None:
        """Smallest lattice size >= n; None when n overflows the lattice."""
        if n <= 0:
            raise ValueError(f"request size must be positive; got {n}")
        for s in self.sizes:
            if n <= s:
                return s
        return None

    def next_larger(self, size: int) -> int | None:
        """The lattice neighbor above ``size`` (the overload-shedding
        "next-larger bucket" rung), or None at the top."""
        for s in self.sizes:
            if s > size:
                return s
        return None


def pad_problem(z, q, size: int, *, seed: int = 0, dtype=None):
    """Pad (z, q) to ``size`` rows with zero-charge tail particles.

    Returns numpy ``(z_pad, q_pad)`` of length ``size``; the first
    ``len(z)`` rows are the caller's, bit-identical. Tail positions are
    uniform in the bounding box of the real points, deterministic in
    ``(seed, size, n)``, and **rejected against exact coincidence** with
    any real point or each other (module docstring: a coincident q=0
    source would 0/0 the harmonic P2P term). Tail charges are exactly 0.

    ``dtype`` is the complex dtype the solver will *compute* in
    (``FmmConfig.complex_dtype``): the coincidence rejection compares
    positions after casting to it, so a tail point distinct in f64 but
    colliding after an f32-config narrows cannot slip through.
    """
    z = np.asarray(z)
    q = np.asarray(q)
    cmp_dtype = np.dtype(dtype) if dtype is not None else z.dtype
    if z.ndim != 1 or z.shape != q.shape:
        raise ShapeError(
            f"pad_problem wants matching 1-D z/q; got z{z.shape} q{q.shape}")
    n = z.size
    if n > size:
        raise ShapeError(f"cannot pad n={n} down into a size-{size} bucket")
    if n == size:
        return z, q
    extra = size - n
    rng = np.random.default_rng(np.random.PCG64((seed, size, n)))
    xmn, xmx = float(z.real.min()), float(z.real.max())
    ymn, ymx = float(z.imag.min()), float(z.imag.max())
    # degenerate spans (all-coincident / axis-collinear input) widen to
    # a relative-epsilon box so rejection sampling terminates
    wx = xmx - xmn
    wy = ymx - ymn
    floor = 1e-6 * max(abs(xmn), abs(xmx), abs(ymn), abs(ymx), 1.0)
    wx = wx if wx > 0 else floor
    wy = wy if wy > 0 else floor
    tail = np.empty(0, dtype=np.complex128)
    z_cmp = z.astype(cmp_dtype)
    while tail.size < extra:
        m = extra - tail.size + 8
        cand = ((xmn + rng.uniform(0.0, 1.0, m) * wx)
                + 1j * (ymn + rng.uniform(0.0, 1.0, m) * wy))
        c_cmp = cand.astype(cmp_dtype)
        keep = (~np.isin(c_cmp, z_cmp)
                & ~np.isin(c_cmp, tail.astype(cmp_dtype)))
        # drop intra-candidate duplicates after the narrowing cast too
        _, first = np.unique(c_cmp, return_index=True)
        uniq = np.zeros(cand.size, dtype=bool)
        uniq[first] = True
        tail = np.concatenate([tail, cand[keep & uniq]])
    qdt = q.dtype if np.issubdtype(q.dtype, np.complexfloating) \
        else np.complex128
    z_pad = np.concatenate([z, tail[:extra].astype(z.dtype)])
    q_pad = np.concatenate([q.astype(qdt), np.zeros(extra, dtype=qdt)])
    return z_pad, q_pad


def unpad(phi, n: int):
    """Slice the real rows back out of a padded result."""
    return np.asarray(phi)[..., :n]

"""The serving plane: ragged, faulty traffic onto the compiled pipeline.

``ServePlane.serve`` accepts a stream of heterogeneous ``(z, q)``
requests and routes them onto the batched 3-launch Pallas pipeline
through three layers (DESIGN.md §10):

  admission     eager, per-request: shape/dtype screening, non-finite
                input refusal (a poison request must not ride into a
                batch — batched health is reduced across rows, so one
                NaN row would fail the whole dispatch), deadline-budget
                checks, oversize triage
  dispatch      shape bucketing (``BucketLattice`` + zero-charge tail
                padding — exact for the real rows), batch-width
                rounding to a power of two, one ``apply_batched``
                guarded call per group through the keyed executable
                cache (``PlanCache``); the ``StragglerMonitor`` from
                the launch runtime flags slow dispatches
  degradation   failures the per-call guard ladder cannot absorb shed
                explicitly, with backoff, per request: next-larger
                bucket -> reference backend -> direct O(N^2) for small
                N -> typed rejection. Every decision lands in a
                structured ``ServeReport``; the plane *never* lets an
                exception escape ``serve`` — a request either returns a
                trustworthy phi or a typed rejection.

Cf. Holm et al. (arXiv:1311.1006): adapt the near/far budget online
from measured conditions; Agullo et al.: a runtime absorbing load
imbalance across FMM phases. This is the jax-native analogue one level
up — absorbing *traffic* imbalance onto fixed compiled shapes.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, NamedTuple, Optional, Sequence

import jax
import numpy as np

from ..core.direct import direct_potential
from ..errors import (DeadlineExceededError, DTypeError, FmmError,
                      NonFiniteInputError, OversizedRequestError, ShapeError)
from ..launch.runtime import StragglerMonitor
from .buckets import BucketLattice, pad_problem, unpad
from .cache import PlanCache, default_cfg_factory

#: ``ServeReport.status`` values, in decreasing order of health.
STATUSES = ("ok", "recovered", "degraded", "rejected")


@dataclasses.dataclass
class Request:
    """One serving request: positions, charges, optional deadline budget
    (seconds from admission). ``rid`` is assigned by the plane when
    None."""

    z: Any
    q: Any
    deadline_s: Optional[float] = None
    rid: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Structured record of every decision made for one request.

    ``status``: "ok" (primary rung, no retries), "recovered" (the guard
    ladder escalated caps and recovered — answer trustworthy),
    "degraded" (served off the fast path: backend degradation, bucket
    reroute, or direct O(N^2) — answer trustworthy, latency/cost
    degraded), "rejected" (no trustworthy answer; ``error`` carries the
    typed error name). ``path`` is the ordered decision trail
    (admission, rungs walked, shed steps). ``slow`` flags a dispatch
    the straggler monitor considered an outlier."""

    rid: int
    n: int
    status: str
    path: tuple[str, ...] = ()
    bucket: Optional[int] = None
    batch: Optional[int] = None
    backend: Optional[str] = None
    cache: Optional[str] = None
    latency_s: float = 0.0
    slow: bool = False
    deadline_s: Optional[float] = None
    deadline_exceeded: bool = False
    retries: int = 0
    error: Optional[str] = None
    error_msg: Optional[str] = None

    def summary(self) -> str:
        trail = " -> ".join(self.path) or "(direct admission)"
        tail = f" error={self.error}" if self.error else ""
        ddl = " DEADLINE-MISS" if self.deadline_exceeded else ""
        slow = " SLOW" if self.slow else ""
        return (f"[serve:req{self.rid}] n={self.n} -> "
                f"bucket={self.bucket}/B={self.batch} "
                f"{self.status} ({trail}) backend={self.backend} "
                f"cache={self.cache} {self.latency_s * 1e3:.1f}ms"
                f"{tail}{ddl}{slow}")


class ServeResult(NamedTuple):
    """(phi, report): phi is a numpy array of length n, or None when
    the request was rejected (``report.error`` says why)."""

    phi: Optional[np.ndarray]
    report: ServeReport


def _batch_width(k: int, max_batch: int) -> int:
    """Round a group size up to the power-of-two batch lattice (<= max):
    one compiled executable per (bucket, width) instead of per count."""
    w = 1
    while w < k and w < max_batch:
        w *= 2
    return min(w, max_batch)


class _Item:
    """Mutable per-request serving state (internal)."""

    def __init__(self, idx: int, req: Request, now: float):
        self.idx = idx
        self.req = req
        self.rid = req.rid if req.rid is not None else idx
        self.t_admit = now
        self.z: Optional[np.ndarray] = None
        self.q: Optional[np.ndarray] = None
        self.n = 0
        self.bucket: Optional[int] = None
        self.path: list[str] = []
        self.result: Optional[ServeResult] = None


class ServePlane:
    """Robust dispatcher from ragged request streams onto the compiled
    batched pipeline (module docstring).

        plane = ServePlane(BucketLattice.geometric(64, 4096))
        results = plane.serve([Request(z1, q1), Request(z2, q2, 0.5)])
        for phi, report in results:
            print(report.summary())

    ``clock``/``sleep`` are injectable for tests and fault injection;
    ``monitor`` is the slow-request detector (a ``StragglerMonitor``
    from the launch runtime — per-dispatch wall time against a rolling
    median)."""

    def __init__(self, lattice: Optional[BucketLattice] = None, *,
                 backend: str = "auto", cfg_factory=None,
                 max_batch: int = 8, direct_max: int = 4096,
                 default_deadline_s: Optional[float] = None,
                 cache_entries: int = 16, max_cap_doublings: int = 3,
                 backoff_s: Sequence[float] = (0.0, 0.02, 0.1),
                 monitor: Optional[StragglerMonitor] = None,
                 clock=time.perf_counter, sleep=time.sleep):
        self.lattice = lattice or BucketLattice.geometric(64, 1 << 14)
        self.backend = backend
        self.cfg_factory = cfg_factory or default_cfg_factory
        self.max_batch = max(1, int(max_batch))
        self.direct_max = direct_max
        self.default_deadline_s = default_deadline_s
        self.backoff_s = tuple(backoff_s)
        self.clock = clock
        self.sleep = sleep
        self.monitor = monitor or StragglerMonitor(window=64,
                                                   threshold=3.0, warmup=1)
        self.cache = PlanCache(self.cfg_factory, backend,
                               max_entries=cache_entries,
                               max_cap_doublings=max_cap_doublings)
        # the shed ladder's reference-backend rung gets its own small
        # cache (only faulted traffic reaches it)
        self._ref_cache = PlanCache(self.cfg_factory, "reference",
                                    max_entries=4,
                                    max_cap_doublings=max_cap_doublings)
        self._rid_counter = itertools.count()
        self._dispatches = 0
        self.counters = {s: 0 for s in STATUSES}
        self.counters.update(requests=0, dispatches=0, slow_dispatches=0,
                             deadline_misses=0, shed_walks=0)

    # -- public API ---------------------------------------------------------

    def warm(self, buckets=None, batches=(1,)) -> list[tuple[int, int]]:
        """Precompile shape classes ahead of traffic (the warm-up
        half of the keyed executable cache)."""
        buckets = list(buckets) if buckets is not None else \
            list(self.lattice.sizes)
        return self.cache.warm_all(buckets, batches)

    def submit(self, z, q, deadline_s: Optional[float] = None) -> ServeResult:
        """Serve one request (convenience over ``serve``)."""
        return self.serve([Request(z, q, deadline_s)])[0]

    def serve(self, requests: Sequence[Request]) -> list[ServeResult]:
        """Serve a wave of requests; results in submission order.

        Never raises for a request-level fault: every request comes back
        as ``(phi, report)`` or ``(None, report-with-typed-error)``."""
        now = self.clock()
        items = [_Item(next(self._rid_counter), r, now) for r in requests]
        self.counters["requests"] += len(items)

        admitted: dict[int, list[_Item]] = {}
        for it in items:
            self._admit(it, admitted)

        for bucket in sorted(admitted):
            queue = admitted[bucket]
            while queue:
                chunk = []
                while queue and len(chunk) < self.max_batch:
                    it = queue.pop(0)
                    if self._deadline_expired(it, "dispatch"):
                        continue
                    chunk.append(it)
                if chunk:
                    self._dispatch(bucket, chunk)

        for it in items:
            if it.result is None:     # pragma: no cover - defensive
                it.result = self._reject(
                    it, FmmError("request fell through the dispatch plan"),
                    "lost")
        return [it.result for it in items]

    def stats(self) -> dict:
        """Cumulative serving counters + per-bucket cache traffic +
        straggler state — the plane's observability surface."""
        return {
            **self.counters,
            "cache": {b: s._asdict() for b, s in self.cache.info().items()},
            "cache_size": len(self.cache),
            "dispatch_median_s": self.monitor.median,
            "slow_requests": list(self.monitor.slow_steps),
        }

    # -- admission ----------------------------------------------------------

    def _admit(self, it: _Item, admitted: dict) -> None:
        req = it.req
        try:
            z = np.asarray(req.z)
            q = np.asarray(req.q)
        except Exception as e:       # not array-able at all
            it.result = self._reject(it, ShapeError(f"unreadable input: {e}"),
                                     "admission")
            return
        if z.ndim != 1 or z.shape != q.shape or z.size == 0:
            it.result = self._reject(it, ShapeError(
                f"serve wants matching non-empty 1-D z/q; got z{z.shape} "
                f"q{q.shape}"), "admission")
            return
        it.n = z.size
        if not np.issubdtype(z.dtype, np.complexfloating):
            it.result = self._reject(it, DTypeError(
                f"serve wants complex positions z = x + iy; got "
                f"{z.dtype.name} (a real-valued position array is a "
                "complex-vs-real confusion)"), "admission")
            return
        if not np.issubdtype(q.dtype, np.complexfloating):
            q = q.astype(np.complex128)
            it.path.append("cast:q-complex")
        if not (np.all(np.isfinite(z.real)) and np.all(np.isfinite(z.imag))
                and np.all(np.isfinite(q.real))
                and np.all(np.isfinite(q.imag))):
            it.result = self._reject(it, NonFiniteInputError(
                "z or q contain NaN/Inf — poison request refused at "
                "admission (it would fail the whole batch)"), "admission")
            return
        it.z, it.q = z, q
        if self._deadline_expired(it, "admission"):
            return
        bucket = self.lattice.bucket_for(it.n)
        if bucket is None:
            if it.n <= self.direct_max:
                it.path.append("oversize->direct")
                self._direct_rung(it)
            else:
                it.result = self._reject(it, OversizedRequestError(
                    f"n={it.n} exceeds the bucket lattice "
                    f"(max {self.lattice.max_size}) and the direct "
                    f"fallback bound ({self.direct_max})"), "admission")
            return
        it.bucket = bucket
        admitted.setdefault(bucket, []).append(it)

    def _remaining(self, it: _Item) -> Optional[float]:
        ddl = it.req.deadline_s if it.req.deadline_s is not None \
            else self.default_deadline_s
        if ddl is None:
            return None
        return ddl - (self.clock() - it.t_admit)

    def _deadline_expired(self, it: _Item, where: str) -> bool:
        rem = self._remaining(it)
        if rem is not None and rem <= 0:
            it.path.append(f"deadline:{where}")
            it.result = self._reject(it, DeadlineExceededError(
                f"deadline budget exhausted at {where} "
                f"({-rem:.3f}s over)"), None)
            return True
        return False

    # -- dispatch -----------------------------------------------------------

    def _dispatch(self, bucket: int, chunk: list[_Item]) -> None:
        width = _batch_width(len(chunk), self.max_batch)
        guarded, hit = self.cache.get(bucket, width)
        cfg = guarded.cfg
        rows_z, rows_q = [], []
        for it in chunk:
            zp, qp = pad_problem(it.z, it.q, bucket,
                                 dtype=cfg.complex_dtype)
            rows_z.append(zp.astype(cfg.complex_dtype))
            rows_q.append(qp.astype(cfg.complex_dtype))
        while len(rows_z) < width:       # filler rows: discard on unpack
            rows_z.append(rows_z[0])
            rows_q.append(rows_q[0])
        zb = jax.numpy.asarray(np.stack(rows_z))
        qb = jax.numpy.asarray(np.stack(rows_q))

        t0 = self.clock()
        step = self._dispatches
        self._dispatches += 1
        self.counters["dispatches"] += 1
        try:
            phi_b, greport = guarded.apply_batched_guarded(zb, qb)
            phi_b = np.asarray(phi_b)
        except Exception as e:
            dt = self.clock() - t0
            self.monitor.record(step, dt)
            for it in chunk:
                it.path.append(f"batch-fault:{type(e).__name__}")
                self._shed(it, e)
            return
        dt = self.clock() - t0
        slow = self.monitor.record(step, dt)
        if slow:
            self.counters["slow_dispatches"] += 1

        rungs = tuple(a.rung for a in greport.attempts)
        if greport.retries == 0:
            status = "ok"
        elif not greport.degradations:
            status = "recovered"
        else:
            status = "degraded"
        for row, it in enumerate(chunk):
            self._finish(it, unpad(phi_b[row], it.n), status,
                         path=it.path + list(rungs),
                         bucket=bucket, batch=width,
                         backend=greport.final_backend,
                         cache="hit" if hit else "miss",
                         retries=greport.retries, slow=slow)

    # -- overload shedding / degradation ------------------------------------

    def _shed(self, it: _Item, first_error: Exception) -> None:
        """Per-request degradation after a failed batch dispatch:
        next-larger bucket -> reference backend -> direct O(N^2) ->
        typed rejection, with backoff between steps."""
        self.counters["shed_walks"] += 1
        last_error = first_error
        steps = []
        nxt = self.lattice.next_larger(it.bucket) if it.bucket else None
        if nxt is not None:
            steps.append(("shed:bucket:%d" % nxt,
                          lambda: self._guarded_single(it, self.cache, nxt)))
        steps.append(("shed:reference",
                      lambda: self._guarded_single(
                          it, self._ref_cache, it.bucket or
                          self.lattice.bucket_for(it.n))))
        backoffs = list(self.backoff_s) + \
            [self.backoff_s[-1]] * max(0, len(steps) + 1 - len(self.backoff_s))
        for (label, fn), backoff in zip(steps, backoffs):
            if self._deadline_expired(it, label):
                return
            if backoff:
                self.sleep(backoff)
            it.path.append(label)
            try:
                phi, greport = fn()
                self._finish(it, phi, "degraded",
                             path=it.path + [a.rung for a in
                                             greport.attempts],
                             bucket=it.bucket, batch=1,
                             backend=greport.final_backend,
                             cache=None, retries=greport.retries)
                return
            except Exception as e:
                last_error = e
                it.path.append(f"failed:{type(e).__name__}")
        if it.n <= self.direct_max:
            if self._deadline_expired(it, "shed:direct"):
                return
            if backoffs:
                self.sleep(backoffs[-1])
            it.path.append("shed:direct")
            try:
                self._direct_rung(it)
                return
            except Exception as e:   # pragma: no cover - direct is capless
                last_error = e
        it.result = self._reject(it, last_error, None)

    def _guarded_single(self, it: _Item, cache: PlanCache, bucket: int):
        """One request through a (bucket, B=1) guarded executable."""
        guarded, _ = cache.get(bucket, 1)
        cfg = guarded.cfg
        zp, qp = pad_problem(it.z, it.q, bucket, dtype=cfg.complex_dtype)
        phi, greport = guarded.apply_guarded(
            jax.numpy.asarray(zp.astype(cfg.complex_dtype)),
            jax.numpy.asarray(qp.astype(cfg.complex_dtype)))
        return unpad(np.asarray(phi), it.n), greport

    def _direct_rung(self, it: _Item) -> None:
        """Capless O(N^2) evaluation at the request's exact N (no
        padding, no buckets — the floor of the degradation ladder)."""
        cfg_kernel = self.cfg_factory(max(
            self.lattice.sizes[0], 4)).kernel
        phi = np.asarray(direct_potential(
            jax.numpy.asarray(it.z), jax.numpy.asarray(it.z),
            jax.numpy.asarray(it.q), kernel=cfg_kernel))
        self._finish(it, phi, "degraded", path=it.path + ["direct"],
                     bucket=None, batch=None, backend="direct", cache=None)

    # -- report assembly ----------------------------------------------------

    def _finish(self, it: _Item, phi: np.ndarray, status: str, *,
                path, bucket, batch, backend, cache, retries: int = 0,
                slow: bool = False) -> None:
        latency = self.clock() - it.t_admit
        ddl = it.req.deadline_s if it.req.deadline_s is not None \
            else self.default_deadline_s
        missed = ddl is not None and latency > ddl
        if missed:
            self.counters["deadline_misses"] += 1
        self.counters[status] += 1
        it.result = ServeResult(phi, ServeReport(
            rid=it.rid, n=it.n, status=status, path=tuple(path),
            bucket=bucket, batch=batch, backend=backend, cache=cache,
            latency_s=latency, slow=slow, deadline_s=ddl,
            deadline_exceeded=missed, retries=retries))

    def _reject(self, it: _Item, error: Exception,
                where: Optional[str]) -> ServeResult:
        if where:
            it.path.append(where)
        latency = self.clock() - it.t_admit
        ddl = it.req.deadline_s if it.req.deadline_s is not None \
            else self.default_deadline_s
        self.counters["rejected"] += 1
        result = ServeResult(None, ServeReport(
            rid=it.rid, n=getattr(it, "n", 0) or 0, status="rejected",
            path=tuple(it.path), bucket=it.bucket, batch=None,
            backend=None, cache=None, latency_s=latency, deadline_s=ddl,
            deadline_exceeded=isinstance(error, DeadlineExceededError),
            error=type(error).__name__, error_msg=str(error)))
        it.result = result
        return result

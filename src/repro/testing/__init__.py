"""Test-support utilities shipped with the package (not test-only:
the CI fault-injection smoke job and operators drilling a deployment
use them too).

  faults        deterministic fault injectors that exercise every rung
                of the guarded-execution recovery ladder
                (repro.solver.guard)
  serve_faults  serving-plane fault injectors (poison request, cache
                thrash, compile storm, latency spike) and the CI soak
                (repro.serve)
"""
from .faults import (force_cap_overflow, nan_coefficients, poison_input,
                     truncate_interaction_lists)
from .serve_faults import (cache_thrash, compile_storm, latency_spike,
                           poison_request)

__all__ = [
    "force_cap_overflow", "nan_coefficients", "poison_input",
    "truncate_interaction_lists",
    "cache_thrash", "compile_storm", "latency_spike", "poison_request",
]

"""Test-support utilities shipped with the package (not test-only:
the CI fault-injection smoke job and operators drilling a deployment
use them too).

  faults   deterministic fault injectors that exercise every rung of
           the guarded-execution recovery ladder (repro.solver.guard)
"""
from .faults import (force_cap_overflow, nan_coefficients, poison_input,
                     truncate_interaction_lists)

__all__ = [
    "force_cap_overflow", "nan_coefficients", "poison_input",
    "truncate_interaction_lists",
]

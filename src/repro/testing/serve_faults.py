"""Deterministic fault injection for the serving plane (DESIGN.md §10).

``repro.testing.faults`` drives the *per-call* recovery ladder; this
module drives the *per-fleet* layer above it — the ``ServePlane``'s
admission control, keyed executable cache, and degradation ladder.
Each injector forces one serving failure mode:

  poison_request    corrupt one request in a stream (NaN charge, Inf
                    position, real-dtype z, or empty arrays) — must be
                    refused at admission as a typed rejection without
                    contaminating the batch it would have ridden in
  cache_thrash      clamp the plan cache to one entry, so every bucket
                    switch evicts and recompiles — eviction counters
                    must tick and results must stay correct
  compile_storm     swap in a dense bucket lattice so nearly every
                    distinct N is its own shape class — the worst-case
                    compile amplification the geometric lattice exists
                    to prevent; serving must stay correct (just slow)
  latency_spike     make every k-th guarded dispatch sleep — the
                    ``StragglerMonitor`` wired into the plane must flag
                    the spiked dispatches ``slow`` in their reports

The context managers patch at instance/class seams and restore on exit.
Unlike the solver-level injectors they do NOT clear the solver cache:
the serving faults are *above* the compiled programs, which stay
healthy throughout.

Run the CI soak (ragged log-normal traffic, every injector, must finish
with zero unhandled exceptions and every fault visible in a report):

    PYTHONPATH=src python -m repro.testing.serve_faults
"""
from __future__ import annotations

import contextlib
import time

import numpy as np

from ..serve.plane import ServePlane
from ..solver.guard import GuardedSolver


# ---------------------------------------------------------------------------
# poison request (admission-control family)
# ---------------------------------------------------------------------------

POISON_KINDS = ("nan-q", "inf-z", "real-z", "empty")


def poison_request(z, q, kind: str = "nan-q", idx: int = 0):
    """Corrupt one (z, q) pair the way ragged traffic does (the same
    flavors ``repro.data.ragged_requests`` injects). Returns new arrays;
    the originals are untouched."""
    z = np.asarray(z)
    q = np.asarray(q)
    if kind == "nan-q":
        q = q.copy()
        q[idx] = np.nan
    elif kind == "inf-z":
        z = z.copy()
        z[idx] = np.inf + 0j
    elif kind == "real-z":
        z = z.real.copy()
    elif kind == "empty":
        z, q = z[:0], q[:0]
    else:
        raise ValueError(f"unknown poison kind {kind!r}; "
                         f"pick from {POISON_KINDS}")
    return z, q


# ---------------------------------------------------------------------------
# cache pressure (keyed-executable-cache family)
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def cache_thrash(plane: ServePlane, max_entries: int = 1):
    """Clamp the plane's executable cache to ``max_entries`` so every
    bucket switch evicts: the eviction path (including the solver-level
    release of compiled programs underneath) runs on every dispatch.
    Restores the original capacity (and nothing else) on exit — evicted
    entries stay evicted, exactly like real cache pressure."""
    orig = plane.cache.max_entries
    plane.cache.max_entries = max(1, int(max_entries))
    while len(plane.cache._entries) > plane.cache.max_entries:
        (b, _, _), _ = plane.cache._entries.popitem(last=False)
        plane.cache._bucket_stats(b)["evictions"] += 1
    try:
        yield plane
    finally:
        plane.cache.max_entries = orig


@contextlib.contextmanager
def compile_storm(plane: ServePlane, step: int = 8):
    """Swap the plane's geometric lattice for a dense stride-``step``
    one: nearly every distinct N becomes its own shape class, so traffic
    that the geometric lattice would serve from a handful of programs
    triggers a compile per size — the worst case the bucketing design
    amortizes. Serving must remain correct under it."""
    from ..serve.buckets import BucketLattice

    orig = plane.lattice
    lo = orig.sizes[0]
    hi = orig.max_size
    dense = tuple(range(lo, hi + 1, max(1, int(step))))
    if dense[-1] != hi:
        dense = dense + (hi,)
    plane.lattice = BucketLattice(sizes=dense)
    try:
        yield plane
    finally:
        plane.lattice = orig


# ---------------------------------------------------------------------------
# latency spike (straggler-detection family)
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def latency_spike(every: int = 3, spike_s: float = 0.25,
                  sleep=time.sleep):
    """Make every ``every``-th guarded batched dispatch sleep ``spike_s``
    before returning — a deterministic straggler. The plane's
    ``StragglerMonitor`` must flag those dispatches (``slow=True`` in
    the affected ``ServeReport``s). Patches at the ``GuardedSolver``
    class seam so it hits cached executables too (the spike is in the
    *launch*, not the program)."""
    real = GuardedSolver.apply_batched_guarded
    state = {"calls": 0}

    def spiked(self, z, q):
        state["calls"] += 1
        out = real(self, z, q)
        if state["calls"] % max(1, int(every)) == 0:
            sleep(spike_s)
        return out

    GuardedSolver.apply_batched_guarded = spiked
    try:
        yield state
    finally:
        GuardedSolver.apply_batched_guarded = real


# ---------------------------------------------------------------------------
# CI soak: ragged traffic through every injector, zero unhandled errors
# ---------------------------------------------------------------------------

def _soak() -> int:     # pragma: no cover - exercised as a CI job
    from ..data.synthetic import ragged_requests
    from ..serve import BucketLattice, Request

    t0 = time.perf_counter()
    failures: list[str] = []

    def gate(name, ok, detail=""):
        print(("ok    " if ok else "FAIL  ") + f"{name:<32s} {detail}")
        if not ok:
            failures.append(name)

    def plane_for(**kw):
        kw.setdefault("max_batch", 4)
        kw.setdefault("direct_max", 512)
        return ServePlane(BucketLattice(sizes=(32, 64, 128)), **kw)

    def traffic(num, seed, poison_rate=0.0, n_max=400):
        return [(Request(z, q), kind) for _, z, q, kind in
                ragged_requests(num, seed=seed, median_n=48, sigma=0.7,
                                n_max=n_max, poison_rate=poison_rate)]

    print("serve-soak: ragged traffic through every serving fault")

    # phase 1 — poisoned ragged stream: every poison refused as a typed
    # rejection, every clean request served, nothing raises
    plane = ServePlane(BucketLattice(sizes=(32, 64, 128)),
                       max_batch=4, direct_max=512)
    waves = [traffic(12, seed=s, poison_rate=0.3) for s in (0, 1)]
    served = rejected = 0
    for wave in waves:
        results = plane.serve([r for r, _ in wave])
        for (req, kind), (phi, rep) in zip(wave, results):
            print("   ", kind, rep.summary())
            if kind == "ok":
                ok = rep.status in ("ok", "recovered", "degraded") \
                    and phi is not None and np.all(np.isfinite(phi))
                served += 1
            else:
                ok = rep.status == "rejected" and rep.error is not None \
                    and phi is None
                rejected += 1
            if not ok:
                failures.append(f"poison-stream:req{rep.rid}:{kind}")
    gate("poison-stream", not failures,
         f"{served} served, {rejected} typed rejections")

    # phase 2 — cache thrash: one-entry cache, alternating buckets;
    # evictions must tick, answers must stay finite
    plane = plane_for()
    with cache_thrash(plane, max_entries=1):
        wave = traffic(8, seed=7, n_max=120)
        results = plane.serve([r for r, _ in wave])
        bad = [rep.rid for phi, rep in results
               if rep.status == "rejected" or phi is None
               or not np.all(np.isfinite(phi))]
    ev = sum(s.evictions for s in plane.cache.info().values())
    gate("cache-thrash", not bad and ev > 0,
         f"evictions={ev}, cache_size={len(plane.cache)}")

    # phase 3 — compile storm: dense lattice, each size its own program;
    # correctness must survive the worst-case compile amplification
    plane = plane_for()
    with compile_storm(plane, step=16):
        wave = traffic(6, seed=11, n_max=120)
        results = plane.serve([r for r, _ in wave])
        bad = [rep.rid for phi, rep in results
               if rep.status == "rejected" or phi is None]
        buckets = {rep.bucket for _, rep in results}
    gate("compile-storm", not bad and len(buckets) >= 3,
         f"{len(buckets)} distinct shape classes compiled")

    # phase 4 — latency spike: every 2nd dispatch sleeps; the straggler
    # monitor must mark at least one dispatch slow in its reports
    plane = plane_for()
    plane.serve([r for r, _ in traffic(6, seed=13, n_max=120)])  # warm
    with latency_spike(every=2, spike_s=0.5):
        results = plane.serve([r for r, _ in traffic(10, seed=17,
                                                     n_max=120)])
    slow = [rep.rid for _, rep in results if rep.slow]
    gate("latency-spike", len(slow) > 0,
         f"slow reports: {slow or 'none'}")

    # phase 5 — deadline pressure: a budget no dispatch can meet must
    # surface as DeadlineExceededError, never hang or raise
    plane = plane_for()
    wave = traffic(4, seed=19, n_max=120)
    results = plane.serve([Request(r.z, r.q, deadline_s=0.0)
                           for r, _ in wave])
    ddl = [rep for phi, rep in results
           if rep.status == "rejected" and rep.error ==
           "DeadlineExceededError" and rep.deadline_exceeded]
    gate("deadline-pressure", len(ddl) == len(results),
         f"{len(ddl)}/{len(results)} shed at admission")

    stats = plane.stats()
    print(f"soak stats (last plane): {stats['requests']} requests, "
          f"{stats['dispatches']} dispatches, "
          f"median dispatch {stats['dispatch_median_s']:.3f}s")
    dt = time.perf_counter() - t0
    print(f"serve-soak: "
          f"{'FAILED ' + ','.join(failures) if failures else 'all ok'} "
          f"({dt:.1f}s, zero unhandled exceptions)")
    return 1 if failures else 0


if __name__ == "__main__":     # pragma: no cover
    raise SystemExit(_soak())

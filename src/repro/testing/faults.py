"""Deterministic fault injection for the guarded-execution ladder.

Each injector forces exactly one failure mode of the failure model
(DESIGN.md §9), so tests and the CI smoke job can drive the recovery
ladder (``repro.solver.guard``) rung by rung instead of hoping a real
fault shows up:

  truncate_interaction_lists  connectivity silently built at caps
                              ``drop`` smaller than the config declares
                              (the cap-drift fault: particles moved past
                              the tuned budget) — honest margins, so the
                              health plane detects it and ONE cap
                              doubling recovers
  force_cap_overflow          connectivity clamped to absolute tiny caps
                              at ANY declared config — cap escalation
                              can never win, the ladder must walk
                              through to the direct O(N^2) rung
  nan_coefficients            a backend phase hook poisoned to emit NaN
                              (the kernel-fault mode) — detected by the
                              non-finite-output flag, recovered by the
                              per-phase degradation rung
  poison_input                NaN planted in z/q (caller-side garbage) —
                              detected by the non-finite-input flag,
                              *unrecoverable* by design: the ladder
                              raises ``NonFiniteInputError`` immediately

The context managers patch at the module/registry seam that the
compiled solvers trace through, and call ``FmmSolver.cache_clear()`` on
enter AND exit: solvers built inside the context trace the fault,
solvers built outside never share programs with them. Build the
``GuardedSolver`` *inside* the context — ``cache_clear`` also releases
compiled programs now (the eviction fix), so a solver built before
entry re-traces on its next call: through the patched module seam while
a connectivity fault is active (it sees the fault), but always with the
backend hooks it captured at construction (a registry poison like
``nan_coefficients`` never leaks into it).

Run the CI smoke walk (every injector, full ladder, interpret mode):

    PYTHONPATH=src python -m repro.testing.faults
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp

from ..core import fmm as _fmm
from ..core.topology import Connectivity
from ..solver.backends import get_backend, register_backend
from ..solver.solver import FmmSolver


# ---------------------------------------------------------------------------
# connectivity truncation (cap-overflow family)
# ---------------------------------------------------------------------------

def _truncate(lst: jax.Array, cap: int) -> jax.Array:
    """Drop list entries beyond ``cap`` (shape stays the declared one)."""
    if lst.shape[-1] <= cap:
        return lst
    return lst.at[..., cap:].set(-1)


def _max_count(arrays) -> jax.Array:
    """Fullest row over a group of padded lists (kept entries are >= 0)."""
    return jnp.stack([(a >= 0).sum(-1).max() for a in arrays]).max()


def _truncated_connectivity(conn: Connectivity, eff_strong: int,
                            eff_weak: int) -> Connectivity:
    """``conn`` as if it had been built at the smaller *effective* caps:
    entries beyond them dropped, margins/overflow recomputed against
    them — the fault is honest, exactly like a real undersized build."""
    margins = jnp.stack([
        eff_strong - _max_count(conn.strong),
        eff_weak - _max_count(conn.weak),
        eff_strong - _max_count([conn.p2p]),
        eff_strong - _max_count([conn.p2l]),
        eff_strong - _max_count([conn.m2p]),
    ]).astype(jnp.int32)
    overflow = jnp.maximum(-margins.min(), 0).astype(jnp.int32)
    return conn._replace(
        strong=tuple(_truncate(s, eff_strong) for s in conn.strong),
        weak=tuple(_truncate(w, eff_weak) for w in conn.weak),
        p2p=_truncate(conn.p2p, eff_strong),
        p2l=_truncate(conn.p2l, eff_strong),
        m2p=_truncate(conn.m2p, eff_strong),
        overflow=overflow, margins=margins)


@contextlib.contextmanager
def _patched_connectivity(effective_caps):
    """Patch the ``build_connectivity`` binding that ``fmm_build`` traces
    (``repro.core.fmm``'s) with a truncating wrapper.
    ``effective_caps(cfg) -> (strong, weak)`` picks the effective caps
    per config, so an escalated config sees proportionally wider
    effective lists — the fault composes with the recovery ladder."""
    real = _fmm.build_connectivity

    def faulty(tree, cfg, leaf_classify_impl=None):
        conn = real(tree, cfg, leaf_classify_impl=leaf_classify_impl)
        es, ew = effective_caps(cfg)
        return _truncated_connectivity(conn, max(1, int(es)),
                                       max(1, int(ew)))

    FmmSolver.cache_clear()
    _fmm.build_connectivity = faulty
    try:
        yield
    finally:
        _fmm.build_connectivity = real
        FmmSolver.cache_clear()


@contextlib.contextmanager
def truncate_interaction_lists(drop: int = 2):
    """Cap-drift fault: every interaction list is silently built ``drop``
    entries short of what the config declares. A config whose margins
    were < ``drop`` overflows; doubling the caps restores slack (the
    effective caps scale with the declared ones), so the guard's cap-
    escalation rung recovers without degrading the backend."""
    with _patched_connectivity(
            lambda cfg: (cfg.strong_cap - drop, cfg.weak_cap - drop)):
        yield


@contextlib.contextmanager
def force_cap_overflow(strong: int = 1, weak: int = 1):
    """Unrecoverable-by-escalation overflow: effective caps clamped to
    tiny absolute values no matter what the config declares. Every cap
    doubling still overflows, so the ladder must fall through to the
    direct O(N^2) rung — the walk the acceptance gate measures."""
    with _patched_connectivity(
            lambda cfg: (min(strong, cfg.strong_cap),
                         min(weak, cfg.weak_cap))):
        yield


# ---------------------------------------------------------------------------
# kernel fault (non-finite output family)
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def nan_coefficients(backend: str = "pallas", phase: str = "eval_fused"):
    """Kernel fault: re-register ``backend`` with its ``phase`` hook
    wrapped to multiply its output by NaN — deterministic non-finite
    coefficients/potentials from one compute phase, finite input. The
    health plane flags ``nonfinite_output``; the guard's per-phase
    degradation rung (reference sweeps for the poisoned phase) recovers.
    """
    be = get_backend(backend)
    hook = getattr(be, phase)
    if hook is None:
        raise ValueError(
            f"backend {backend!r} has no {phase!r} hook to poison "
            "(already the reference path?)")

    def poisoned(*args, **kwargs):
        out = hook(*args, **kwargs)
        return jax.tree_util.tree_map(lambda a: a * jnp.nan, out)

    FmmSolver.cache_clear()
    register_backend(dataclasses.replace(be, **{phase: poisoned}))
    try:
        yield
    finally:
        register_backend(be)
        FmmSolver.cache_clear()


# ---------------------------------------------------------------------------
# input fault (non-finite input family)
# ---------------------------------------------------------------------------

def poison_input(arr: jax.Array, idx: int = 0) -> jax.Array:
    """Plant a NaN at ``arr[..., idx]`` — caller-side garbage input. The
    guard refuses it (``NonFiniteInputError``): no recovery rung can
    repair an input that carries no information."""
    return jnp.asarray(arr).at[..., idx].set(jnp.nan)


# ---------------------------------------------------------------------------
# CI smoke walk: every injector drives its rung of the ladder
# ---------------------------------------------------------------------------

def _smoke() -> int:     # pragma: no cover - exercised as a CI job
    import numpy as np

    jax.config.update("jax_enable_x64", True)   # f64 parity vs the oracle

    from ..core.config import FmmConfig
    from ..core.direct import direct_potential
    from ..data.synthetic import particles
    from ..errors import NonFiniteInputError
    from ..solver.guard import GuardedSolver

    cfg = FmmConfig(n=256, nlevels=2, p=12, dtype="f64",
                    strong_cap=32, weak_cap=64)
    z, q = particles("normal", cfg.n, 3)
    z, q = jnp.asarray(z), jnp.asarray(q)
    oracle = np.asarray(direct_potential(z, z, q, kernel=cfg.kernel))
    scale = np.abs(oracle).max()
    failures = []

    def check(name, report, phi, expect_rung, tol):
        err = np.abs(np.asarray(phi) - oracle).max() / scale
        line = (f"  {name:<28s} {report.summary()}  rel_err={err:.2e}")
        ok = report.ok and expect_rung in [a.rung for a in report.attempts]
        ok = ok and err < tol
        print(("ok " if ok else "FAIL ") + line)
        if not ok:
            failures.append(name)

    print("fault-injection smoke: walking the recovery ladder")

    # rung 0: healthy primary — no retries, phi at FMM accuracy
    g = GuardedSolver(cfg, "reference", max_cap_doublings=2)
    phi, rep = g.apply_guarded(z, q)
    check("healthy", rep, phi, "primary", 1e-6)
    assert rep.retries == 0, rep.summary()

    # rung 1: cap drift -> one doubling recovers on the fast path; the
    # margins are per-class, so only the overflowed strong family grows
    with truncate_interaction_lists(drop=20):
        g = GuardedSolver(cfg, "reference", max_cap_doublings=2)
        phi, rep = g.apply_guarded(z, q)
        check("truncate->caps*2", rep, phi,
              f"caps*{2 * cfg.strong_cap}/{cfg.weak_cap}", 1e-6)
        assert rep.degradations == (), rep.summary()

    # rung 2: poisoned kernel -> per-phase degradation recovers
    with nan_coefficients("pallas", "eval_fused"):
        g = GuardedSolver(cfg, "pallas", max_cap_doublings=2)
        phi, rep = g.apply_guarded(z, q)
        check("nan-kernel->degrade", rep, phi, "degrade:pallas+ref-eval",
              1e-6)

    # rung 3: overflow at any caps -> the direct O(N^2) last resort,
    # exact parity with the oracle
    with force_cap_overflow(strong=1, weak=1):
        g = GuardedSolver(cfg, "reference", max_cap_doublings=1)
        phi, rep = g.apply_guarded(z, q)
        check("forced-overflow->direct", rep, phi, "direct", 1e-10)

    # garbage input: typed refusal, not a recovery attempt
    g = GuardedSolver(cfg, "reference")
    try:
        g.apply_guarded(poison_input(z), q)
        print("FAIL  nan-input did not raise")
        failures.append("nan-input")
    except NonFiniteInputError:
        print("ok    nan-input -> NonFiniteInputError (unrecoverable)")

    print("smoke:", "FAILED " + ",".join(failures) if failures else "all ok")
    return 1 if failures else 0


if __name__ == "__main__":     # pragma: no cover
    raise SystemExit(_smoke())

"""Optimized-HLO analysis: collective bytes with while-loop trip counts.

``compiled.cost_analysis()`` and a naive text scan both count a while body
ONCE (measured: a 10-iteration scan of matmuls reports 1 matmul of flops),
so per-step collective bytes must be weighted by the loop trip counts. XLA
annotates scan-derived loops with ``known_trip_count`` in backend_config;
we build the computation call graph (while bodies/conditions, fusion
`calls`, `to_apply`) and propagate multipliers from ENTRY.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
    "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# NOTE: while-body params are tuple-typed (nested parens), so only anchor
# on "column-0 %name (" — never try to match the full signature.
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=\n]*?)\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z0-9.]*\(")
_CALL_RE = re.compile(r"(?:body|calls|to_apply|condition)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count\W+n\W+(\d+)")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, str]:
    """computation name -> body text.

    Line-based: the HLO pretty-printer opens a computation with a def line
    at column 0 and closes it with a lone '}' at column 0 (brace counting
    is unreliable — layouts/backend_configs contain braces)."""
    comps: dict[str, str] = {}
    cur: str | None = None
    buf: list[str] = []
    for line in hlo.split("\n"):
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                buf = [line]
        else:
            if line.startswith("}"):
                comps[cur] = "\n".join(buf)
                cur = None
                buf = []
            else:
                buf.append(line)
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps


def collective_bytes_weighted(hlo: str) -> dict:
    """Collective bytes per category, weighted by loop trip counts."""
    comps = _split_computations(hlo)
    entry = None
    for line in hlo.split("\n"):
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda k: len(comps[k])) if comps else None
    out: dict[str, float] = {}
    if entry is None:
        return {"total": 0.0}

    seen: set[tuple[str, int]] = set()

    def visit(name: str, mult: int):
        if (name, mult) in seen or name not in comps or mult <= 0:
            return
        seen.add((name, mult))
        body = comps[name]
        for m in _COLL_RE.finditer(body):
            kind = m.group(2)
            out[kind] = out.get(kind, 0.0) + mult * shape_bytes(m.group(1))
        for line in body.split("\n"):
            if " while(" in line:
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                for cm in _CALL_RE.finditer(line):
                    # condition runs trip+1 times but holds no collectives
                    visit(cm.group(1), mult * trip)
            else:
                for cm in _CALL_RE.finditer(line):
                    visit(cm.group(1), mult)

    visit(entry, 1)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out

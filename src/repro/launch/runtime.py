"""Training-loop runtime pieces: straggler monitoring, failure injection,
and the generic fault-tolerant step loop shared by launch/train.py and the
examples.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import numpy as np


class StragglerMonitor:
    """Per-step wall-time tracker.

    At cluster scale the same EWMA/median logic runs per worker and feeds
    the coordinator's slow-node eviction; here it logs slow steps (compile
    steps are excluded via warmup) so stalls are visible in the step log.

    The FMM serving plane (``repro.serve.plane.ServePlane``) wires one of
    these around every guarded batched dispatch as its slow-request
    detector: a dispatch beyond ``threshold``x the rolling median flags
    ``slow=True`` on every ``ServeReport`` in that batch (drilled by the
    ``latency_spike`` injector in ``repro.testing.serve_faults``).
    """

    def __init__(self, window: int = 50, threshold: float = 2.5,
                 warmup: int = 2):
        self.times: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.warmup = warmup
        self.slow_steps: list[tuple[int, float]] = []
        self._seen = 0

    def record(self, step: int, dt: float) -> bool:
        self._seen += 1
        if self._seen <= self.warmup:
            return False
        slow = False
        if len(self.times) >= 5:
            med = float(np.median(self.times))
            if dt > self.threshold * med:
                self.slow_steps.append((step, dt))
                slow = True
        self.times.append(dt)
        return slow

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else float("nan")


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for fault-tolerance tests: raises at
    the given steps (simulating a lost worker) exactly once each."""

    fail_at: tuple[int, ...] = ()
    _done: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self._done:
            self._done.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


def train_loop(step_fn: Callable, state, batch_fn: Callable, *,
               start_step: int, num_steps: int,
               ckpt_manager=None, ckpt_every: int = 0,
               monitor: StragglerMonitor | None = None,
               failure: FailureInjector | None = None,
               log_every: int = 10, log_fn=print) -> tuple[Any, dict]:
    """Generic loop: state = step_fn(state, batch, step). Returns
    (state, summary). Checkpoints asynchronously every ``ckpt_every``.
    """
    monitor = monitor or StragglerMonitor()
    losses = []
    step = start_step
    for step in range(start_step, num_steps):
        if failure is not None:
            failure.check(step)
        batch = batch_fn(step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch, step)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = monitor.record(step, dt)
        losses.append(loss)
        if log_every and step % log_every == 0:
            log_fn(f"step {step:5d} loss {loss:8.4f} "
                   f"dt {dt*1e3:8.1f}ms{'  [SLOW]' if slow else ''}")
        if ckpt_manager is not None and ckpt_every and \
                (step + 1) % ckpt_every == 0:
            ckpt_manager.save(step + 1, state)
    if ckpt_manager is not None:
        ckpt_manager.wait()
    return state, {
        "last_step": step,
        "losses": losses,
        "median_step_time": monitor.median,
        "slow_steps": monitor.slow_steps,
    }

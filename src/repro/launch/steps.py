"""Sharded step functions per (arch x shape): train / prefill / decode.

All sharding decisions live here:

  params      logical axes -> Rules table (TP/EP on "model", FSDP over the
              data axes for the "embed" axis)
  activations batch over (pod, data); residual stream sequence-sharded over
              "model" between blocks (Megatron-style sequence parallelism —
              without it the 18k-wide archs cannot hold their per-layer
              residuals)
  KV cache    sequence axis over "model" (uniform for any n_kv; distributed
              flash-decode emerges from GSPMD's partitioned softmax
              reductions), batch over data axes when divisible
  optimizer   mirrors the params (factored Adafactor rows/cols drop the
              corresponding spec entries)

Gradient accumulation: the global batch is split into microbatches scanned
inside one jit (grads accumulated in f32), so arbitrary global batches fit.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from ..data.synthetic import batch_specs
from ..models import lm
from ..models.config import ModelConfig
from ..optim import OptConfig, apply_updates, init_opt_state
from ..parallel.sharding import Rules, dp_axes


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, rules: Rules):
    return lm.make_param_pspecs(cfg, rules.table())


def opt_specs(cfg: ModelConfig, oc: OptConfig, rules: Rules):
    """Optimizer-state PartitionSpecs mirroring the parameter specs."""
    pspecs = param_specs(cfg, rules)
    aparams = lm.make_abstract_params(cfg)
    if oc.name == "adamw":
        return {"m": pspecs, "v": pspecs}

    def vrow(spec, p):
        from ..optim.optim import _factored
        return PS(*spec[:-1]) if _factored(p.shape, oc.factored_min_dim) \
            else spec

    def vcol(spec, p):
        from ..optim.optim import _factored
        if _factored(p.shape, oc.factored_min_dim):
            return PS(*(tuple(spec)[:-2] + tuple(spec)[-1:]))
        return PS(*((None,) * p.ndim))

    return {
        "vr": jax.tree.map(vrow, pspecs, aparams),
        "vc": jax.tree.map(vcol, pspecs, aparams),
        "m": pspecs,
    }


def _dp_if_divisible(n: int, mesh, multi_pod: bool):
    axes = dp_axes(multi_pod)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return axes if n % size == 0 else None


def batch_pspecs(cfg: ModelConfig, batch: int, mesh, multi_pod: bool):
    dp = _dp_if_divisible(batch, mesh, multi_pod)
    spec = {"tokens": PS(dp, None), "labels": PS(dp, None)}
    if cfg.arch == "encdec":
        spec["audio"] = PS(dp, None, None)
    if cfg.arch == "vlm":
        spec["img"] = PS(dp, None, None)
    return spec


def cache_pspecs(cfg: ModelConfig, batch: int, mesh, multi_pod: bool,
                 max_len: int = 0):
    """KV-cache specs: seq over "model", batch over data axes."""
    dp = _dp_if_divisible(batch, mesh, multi_pod)
    tp = int(mesh.shape["model"])
    seq_ax = "model" if (max_len == 0 or max_len % tp == 0) else None
    cross_ax = "model" if cfg.n_audio_ctx % tp == 0 else None
    spec: dict[str, Any] = {}
    for i, (mixer, ffn) in enumerate(cfg.group):
        e = {}
        if mixer == "attn":
            e["k"] = PS(None, dp, seq_ax, None, None)
            e["v"] = PS(None, dp, seq_ax, None, None)
        elif mixer == "mamba":
            e["conv"] = PS(None, dp, None, "model")
            e["h"] = PS(None, dp, "model", None)
        elif mixer == "rwkv":
            e["prev_tm"] = PS(None, dp, None, None)
            e["s"] = PS(None, dp, "model", None, None)
        if ffn == "rwkv_cm":
            e["prev_cm"] = PS(None, dp, None, None)
        if cfg.arch == "encdec":
            e["ck"] = PS(None, dp, cross_ax, None, None)
            e["cv"] = PS(None, dp, cross_ax, None, None)
        spec[f"l{i}"] = e
    return spec


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, PS))


# ---------------------------------------------------------------------------
# abstract inputs (dry-run: ShapeDtypeStruct only, no allocation)
# ---------------------------------------------------------------------------

def abstract_train_state(cfg: ModelConfig, oc: OptConfig):
    aparams = lm.make_abstract_params(cfg)
    astate = jax.eval_shape(lambda p: init_opt_state(p, oc), aparams)
    return {"params": aparams, "opt": astate,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        functools.partial(lm.init_cache, cfg, batch, max_len))


def train_state_pspecs(cfg: ModelConfig, oc: OptConfig, rules: Rules):
    return {"params": param_specs(cfg, rules),
            "opt": opt_specs(cfg, oc, rules),
            "step": PS()}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, oc: OptConfig, *, num_micro: int = 1):
    """(state, batch) -> (state, metrics); microbatch scan inside."""

    def loss_fn(params, batch):
        return lm.forward_loss(params, batch, cfg)

    def train_step(state, batch):
        params = state["params"]

        def micro_slice(x):
            gb = x.shape[0]
            return x.reshape((num_micro, gb // num_micro) + x.shape[1:])

        mbatches = jax.tree.map(micro_slice, batch)
        gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            gacc, lacc, lb, z = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                gacc, grads)
            return (gacc, lacc + loss,
                    lb + metrics.get("load_balance", 0.0),
                    z + metrics.get("router_z", 0.0)), None

        z0 = jnp.zeros((), jnp.float32)
        (gacc, loss, lb, z), _ = jax.lax.scan(
            body, (gz, z0, z0, z0), mbatches)
        grads = jax.tree.map(lambda g: g / num_micro, gacc)
        new_params, new_opt, stats = apply_updates(
            params, grads, state["opt"], state["step"], oc)
        metrics = {"loss": loss / num_micro,
                   "load_balance": lb / num_micro,
                   "router_z": z / num_micro, **stats}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step


def make_prefill(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, max_len)
    return prefill_step


def make_decode(cfg: ModelConfig):
    def serve_step(params, cache, tokens, cur_index):
        return lm.decode_step(params, cache, tokens, cur_index, cfg)
    return serve_step


# ---------------------------------------------------------------------------
# jit + shardings assembly for one (arch, shape, mesh) cell
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoweredCell:
    kind: str
    jitted: Any
    args: tuple          # abstract or concrete args matching jitted


def build_cell(cfg: ModelConfig, oc: OptConfig, shape, mesh,
               multi_pod: bool, *, micro_tokens: int = 8192):
    """Assemble the jit'd step + abstract inputs for a dry-run cell."""
    rules = Rules(multi_pod=multi_pod, fsdp=True)
    kind = shape.kind
    B, S = shape.global_batch, shape.seq

    if kind == "train":
        dp = int(np.prod([mesh.shape[a] for a in dp_axes(multi_pod)]))
        per_replica = max(1, B // dp)
        # microbatches: cap per-replica micro tokens
        mt = max(1, micro_tokens // S)
        num_micro = max(1, per_replica // mt)
        step_fn = make_train_step(cfg, oc, num_micro=num_micro)
        state = abstract_train_state(cfg, oc)
        sspec = train_state_pspecs(cfg, oc, rules)
        bspec = batch_pspecs(cfg, B, mesh, multi_pod)
        babs = batch_specs(cfg, B, S)
        jitted = jax.jit(
            step_fn,
            in_shardings=(to_shardings(mesh, sspec),
                          to_shardings(mesh, bspec)),
            out_shardings=(to_shardings(mesh, sspec), None),
            donate_argnums=(0,),
        )
        return LoweredCell("train", jitted, (state, babs))

    pspec = param_specs(cfg, rules)
    aparams = lm.make_abstract_params(cfg)

    if kind == "prefill":
        step_fn = make_prefill(cfg, S)
        bspec = batch_pspecs(cfg, B, mesh, multi_pod)
        cspec = cache_pspecs(cfg, B, mesh, multi_pod, S)
        babs = batch_specs(cfg, B, S)
        babs.pop("labels")
        bspec = {k: v for k, v in bspec.items() if k in babs}
        jitted = jax.jit(
            step_fn,
            in_shardings=(to_shardings(mesh, pspec),
                          to_shardings(mesh, bspec)),
            out_shardings=(to_shardings(mesh, cspec), None),
        )
        return LoweredCell("prefill", jitted, (aparams, babs))

    # decode: one token against a full cache of length S
    step_fn = make_decode(cfg)
    cspec = cache_pspecs(cfg, B, mesh, multi_pod, S)
    cache = abstract_cache(cfg, B, S)
    dp = _dp_if_divisible(B, mesh, multi_pod)
    toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(
        step_fn,
        in_shardings=(to_shardings(mesh, pspec),
                      to_shardings(mesh, cspec),
                      NamedSharding(mesh, PS(dp, None)),
                      NamedSharding(mesh, PS())),
        out_shardings=(NamedSharding(mesh, PS(dp, "model")),
                       to_shardings(mesh, cspec)),
        donate_argnums=(1,),
    )
    return LoweredCell("decode", jitted, (aparams, cache, toks, idx))

"""Launchers: mesh definitions, multi-pod dry-run, train/serve entry points.

NOTE: do not import .dryrun from here — it sets XLA_FLAGS at import time
(512 host devices) and must only be imported as __main__.
"""
from .mesh import make_production_mesh, make_test_mesh, mesh_info
from .runtime import FailureInjector, StragglerMonitor, train_loop

__all__ = ["make_production_mesh", "make_test_mesh", "mesh_info",
           "FailureInjector", "StragglerMonitor", "train_loop"]

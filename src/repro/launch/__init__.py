"""Deployment runtime: mesh definitions, fault-tolerant step loop, and
HLO collective accounting — the generic substrate a production FMM
service runs on (the LM train/serve/dry-run cells that shipped with the
seed scaffold were removed)."""
from .mesh import make_production_mesh, make_test_mesh, mesh_info
from .runtime import FailureInjector, StragglerMonitor, train_loop

__all__ = ["make_production_mesh", "make_test_mesh", "mesh_info",
           "FailureInjector", "StragglerMonitor", "train_loop"]

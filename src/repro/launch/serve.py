"""Serving launcher: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
        --batch 4 --prompt-len 32 --gen 32

Exercises the same prefill/decode_step functions the dry-run lowers for the
decode_32k / long_500k cells.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from ..configs import smoke_config, get_config
    from ..data.synthetic import DataConfig, lm_batch
    from ..models import lm

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    max_len = args.prompt_len + args.gen
    params = lm.make_params(cfg, args.seed)

    dc = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.prompt_len,
                    seed=args.seed)
    batch = lm_batch(dc, 0, cfg)

    prefill = jax.jit(lambda p, b: lm.prefill(p, b, cfg, max_len))
    decode = jax.jit(lambda p, c, t, i: lm.decode_step(p, c, t, i, cfg))

    t0 = time.perf_counter()
    cache, logits = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] {cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f} ms; decode {args.gen-1} steps at "
          f"{tps:.1f} tok/s (incl first-step compile)")
    print("[serve] sample continuations:")
    for b in range(min(args.batch, 2)):
        print("  prompt", np.asarray(batch["tokens"])[b, -8:].tolist(),
              "->", gen[b, :12].tolist())
    return gen


if __name__ == "__main__":
    main()

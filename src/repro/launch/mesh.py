"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS for 512 host devices before any jax initialization, and smoke
tests see the single real device.

Topology: TPU v5e pods of 256 chips as a 16x16 ("data", "model") torus;
multi-pod adds a leading "pod" axis over the (slower) inter-pod links —
collectives we place on "pod" are the ones gradient compression targets.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests running with forced host devices."""
    return jax.make_mesh(shape, axes)


def mesh_info(mesh) -> dict:
    return {
        "axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
    }

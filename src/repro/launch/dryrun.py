import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch dbrx-132b ...] [--shape train_4k ...] \
        [--mesh pod multipod] [--out artifacts/dryrun] [--force]

For each cell this:
  1. builds the production mesh (16x16 "data","model"; 2x16x16 +"pod"),
  2. ``jax.jit(step).lower(*abstract_args)`` (ShapeDtypeStruct — zero
     allocation) and ``.compile()`` — sharding or memory incoherence fails
     HERE, which is the point of the exercise,
  3. prints ``compiled.memory_analysis()`` / ``cost_analysis()``,
  4. parses the optimized HLO for collective bytes,
  5. writes one JSON artifact per cell (resumable: existing cells skip).

The per-device HBM budget check against the 16 GiB of a v5e chip is
reported in the artifact (argument+output+temp bytes).
"""
import argparse
import json
import re
import sys
import time
import traceback


HW = {
    "peak_flops_bf16": 197e12,   # per chip, TPU v5e
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
    "hbm_bytes": 16 * 1024**3,
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
    "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z]*\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(2)
        out[kind] = out.get(kind, 0) + shape_bytes(m.group(1))
    out["total"] = sum(out.values())
    return out


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is None:
        return {}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" not in k)}


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             force: bool = False) -> dict:
    import jax
    from ..configs import SHAPES, applicable, get_config, get_opt
    from .mesh import make_production_mesh
    from .steps import build_cell

    os.makedirs(out_dir, exist_ok=True)
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape_name)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if not ok:
        record.update(status="skipped", reason=reason)
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"[dryrun] {cell_id}: SKIPPED ({reason})")
        return record

    multi_pod = mesh_name == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.devices.size)
    t0 = time.time()
    try:
        with jax.set_mesh(mesh):
            cell = build_cell(cfg, get_opt(arch), shape, mesh, multi_pod)
            lowered = cell.jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = memory_analysis_dict(compiled)
            cost = cost_analysis_dict(compiled)
            hlo = compiled.as_text()
            from .hlo_analysis import collective_bytes_weighted
            coll = collective_bytes_weighted(hlo)
            coll_once = collective_bytes(hlo)
    except Exception as e:
        record.update(status="failed", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"[dryrun] {cell_id}: FAILED {type(e).__name__}: {e}")
        return record

    flops = cost.get("flops", 0.0)
    bytes_acc = cost.get("bytes accessed", 0.0)
    terms = {
        "compute_s": flops / HW["peak_flops_bf16"],
        "memory_s": bytes_acc / HW["hbm_bw"],
        "collective_s": coll.get("total", 0.0) / HW["ici_bw"],
    }
    dominant = max(terms, key=terms.get)
    # useful model flops (per device): 6ND train / 2ND forward
    tokens = shape.global_batch * (shape.seq if cell.kind != "decode" else 1)
    nd_const = 6 if cell.kind == "train" else 2
    model_flops = nd_const * record["active_params"] * tokens / n_chips
    record.update(
        status="ok", kind=cell.kind, n_chips=n_chips,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory=mem, cost=cost, collectives=coll,
        collectives_once=coll_once,
        roofline_terms_s=terms, dominant=dominant,
        model_flops_per_chip=model_flops,
        useful_flops_fraction=(model_flops / flops) if flops else None,
        hbm_used=sum(mem.get(k, 0) for k in
                     ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes")),
        hbm_budget=HW["hbm_bytes"],
    )
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[dryrun] {cell_id}: OK dominant={dominant} "
          f"terms={{compute {terms['compute_s']:.3e}s, "
          f"memory {terms['memory_s']:.3e}s, "
          f"coll {terms['collective_s']:.3e}s}} "
          f"hbm={record['hbm_used']/2**30:.2f}GiB "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    sys.stdout.flush()
    return record


def run_fmm_cell(shape_name: str, mesh_name: str, out_dir: str,
                 force: bool = False) -> dict:
    """The paper's own config: fmm_potential sharded over the full mesh."""
    import jax
    import jax.numpy as jnp
    from ..configs.fmm2d import FMM_SHAPES, fmm_config
    from ..core.fmm import fmm_potential
    from .mesh import make_production_mesh

    os.makedirs(out_dir, exist_ok=True)
    cell_id = f"fmm2d__{shape_name}__{mesh_name}"
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    n = FMM_SHAPES[shape_name]
    cfg = fmm_config(n)
    multi_pod = mesh_name == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    from jax.sharding import NamedSharding, PartitionSpec as PS
    flat = PS(tuple(mesh.axis_names))
    record: dict = {"arch": "fmm2d", "shape": shape_name, "mesh": mesh_name,
                    "n": n, "nlevels": cfg.nlevels, "p": cfg.p}
    t0 = time.time()
    try:
        with jax.set_mesh(mesh):
            fn = jax.jit(lambda z, q: fmm_potential(z, q, cfg),
                         in_shardings=(NamedSharding(mesh, flat),) * 2,
                         out_shardings=NamedSharding(mesh, flat))
            az = jax.ShapeDtypeStruct((n,), jnp.complex64)
            aq = jax.ShapeDtypeStruct((n,), jnp.complex64)
            lowered = fn.lower(az, aq)
            compiled = lowered.compile()
            mem = memory_analysis_dict(compiled)
            cost = cost_analysis_dict(compiled)
            from .hlo_analysis import collective_bytes_weighted
            coll = collective_bytes_weighted(compiled.as_text())
    except Exception as e:
        record.update(status="failed", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"[dryrun] {cell_id}: FAILED {type(e).__name__}: {e}")
        return record
    flops = cost.get("flops", 0.0)
    terms = {
        "compute_s": flops / HW["peak_flops_bf16"],
        "memory_s": cost.get("bytes accessed", 0.0) / HW["hbm_bw"],
        "collective_s": coll.get("total", 0.0) / HW["ici_bw"],
    }
    record.update(status="ok", kind="fmm", n_chips=int(mesh.devices.size),
                  compile_s=round(time.time() - t0, 1), memory=mem,
                  cost=cost, collectives=coll, roofline_terms_s=terms,
                  dominant=max(terms, key=terms.get))
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[dryrun] {cell_id}: OK dominant={record['dominant']}")
    return record


def main():
    from ..configs import ARCH_NAMES, SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=list(ARCH_NAMES) + ["fmm2d"])
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--mesh", nargs="*", default=["pod", "multipod"],
                    choices=["pod", "multipod"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    results = []
    for arch in args.arch:
        if arch == "fmm2d":
            from ..configs.fmm2d import FMM_SHAPES
            shapes = args.shape or list(FMM_SHAPES)
            for sh in shapes:
                if sh not in FMM_SHAPES:
                    continue
                for mesh_name in args.mesh:
                    results.append(run_fmm_cell(sh, mesh_name, args.out,
                                                args.force))
            continue
        shapes = args.shape or list(SHAPES)
        for sh in shapes:
            for mesh_name in args.mesh:
                results.append(run_cell(arch, sh, mesh_name, args.out,
                                        args.force))
    n_ok = sum(r.get("status") == "ok" for r in results)
    n_skip = sum(r.get("status") == "skipped" for r in results)
    n_fail = sum(r.get("status") == "failed" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed "
          f"of {len(results)}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())

"""Training launcher.

Smoke scale (this CPU container):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --ckpt-dir runs/qwen3

Production scale (TPU pods): the same entry point with --no-smoke builds
the full config on the production mesh; the per-cell sharding assembly is
the one exercised by the multi-pod dry-run (launch/dryrun.py), so what
compiles there launches here.

Fault tolerance: auto-restores the latest checkpoint in --ckpt-dir (so a
re-launched job continues), saves asynchronously every --ckpt-every steps,
logs slow steps (straggler monitor), and --fail-at N simulates a worker
loss at step N to exercise the restart path end to end.
"""
from __future__ import annotations

import argparse



def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)

    import jax
    from ..checkpoint import CheckpointManager, latest_step
    from ..configs import get_config, get_opt, smoke_config
    from ..data.synthetic import DataConfig, lm_batch
    from ..launch.runtime import (FailureInjector, StragglerMonitor,
                                  train_loop)
    from ..launch.steps import make_train_step
    from ..models import lm
    from ..optim import init_opt_state
    import dataclasses
    import jax.numpy as jnp

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    oc = dataclasses.replace(get_opt(args.arch), lr=args.lr, warmup=10,
                             total_steps=args.steps)
    dc = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                    seed=args.seed)

    params = lm.make_params(cfg, args.seed)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.2f}M params, "
          f"batch {args.batch} x seq {args.seq}, {args.steps} steps")

    state = {"params": params,
             "opt": init_opt_state(params, oc),
             "step": jnp.zeros((), jnp.int32)}

    cm = None
    start = 0
    if args.ckpt_dir:
        cm = CheckpointManager(args.ckpt_dir, keep=3)
        if latest_step(args.ckpt_dir) is not None:
            state, start = cm.restore_latest()
            state["step"] = jnp.asarray(state["step"])
            print(f"[train] restored checkpoint at step {start}")

    step_fn = jax.jit(make_train_step(cfg, oc, num_micro=1),
                      donate_argnums=(0,))

    def wrapped_step(state, batch, step):
        state, metrics = step_fn(state, batch)
        return state, metrics

    failure = FailureInjector((args.fail_at,)) if args.fail_at >= 0 else None
    state, summary = train_loop(
        wrapped_step, state,
        lambda s: lm_batch(dc, s, cfg),
        start_step=start, num_steps=args.steps,
        ckpt_manager=cm, ckpt_every=args.ckpt_every,
        monitor=StragglerMonitor(), failure=failure)

    losses = summary["losses"]
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(median step {summary['median_step_time']*1e3:.0f} ms, "
          f"{len(summary['slow_steps'])} slow steps)")
    return summary


if __name__ == "__main__":
    main()

"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936, qk_norm, head_dim=128. [hf:Qwen/Qwen3-0.6B]"""
from ..models.config import ModelConfig
from ..optim import OptConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", n_layers=28, d_model=1024, n_heads=16, n_kv=8,
    d_head=128, d_ff=3072, vocab=151936, qk_norm=True, act="silu",
    glu=True, norm="rms", pos="rope", rope_theta=1e6, tie_embeddings=True,
)
OPT = OptConfig(name="adamw", lr=3e-4)

"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
from ..models.config import ModelConfig
from ..optim import OptConfig

CONFIG = ModelConfig(
    name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48, n_kv=8,
    d_ff=10752, vocab=100352, group=(("attn", "moe"),), n_experts=16,
    top_k=4, act="silu", glu=True, norm="rms", pos="rope", rope_theta=5e5,
)
OPT = OptConfig(name="adafactor", lr=2e-4)

"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, QKV bias. [arXiv:2407.10671]"""
from ..models.config import ModelConfig
from ..optim import OptConfig

CONFIG = ModelConfig(
    name="qwen2-72b", n_layers=80, d_model=8192, n_heads=64, n_kv=8,
    d_ff=29568, vocab=152064, qkv_bias=True, act="silu", glu=True,
    norm="rms", pos="rope", rope_theta=1e6,
)
OPT = OptConfig(name="adafactor", lr=2e-4)

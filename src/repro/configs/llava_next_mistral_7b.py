"""llava-next-mistral-7b [vlm]: mistral-7b backbone 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000; anyres image tiling is a STUB — the
frontend supplies precomputed patch embeddings (per the assignment) which a
trained 2-layer MLP projector maps into the LM.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from ..models.config import ModelConfig
from ..optim import OptConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", arch="vlm", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=32000, act="silu", glu=True,
    norm="rms", pos="rope", rope_theta=1e6,
    n_img_tokens=576, img_feat_dim=1024,
)
OPT = OptConfig(name="adafactor", lr=2e-4)

"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attn 7:1 interleave, MoE on
every other layer. [arXiv:2403.19887]"""
from ..models.config import ModelConfig
from ..optim import OptConfig

_GROUP = (
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("attn", "moe"),
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
)
CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", n_layers=72, d_model=8192, n_heads=64,
    n_kv=8, d_ff=24576, vocab=65536, group=_GROUP, n_experts=16, top_k=2,
    act="silu", glu=True, norm="rms", pos="none",  # jamba: no positional enc
    d_state=16, d_conv=4, mamba_expand=2,
)
OPT = OptConfig(name="adafactor", lr=2e-4)

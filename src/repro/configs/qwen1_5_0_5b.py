"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (MHA kv=16) d_ff=2816
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from ..models.config import ModelConfig
from ..optim import OptConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", n_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_ff=2816, vocab=151936, qkv_bias=True, act="silu", glu=True,
    norm="rms", pos="rope", rope_theta=1e6, tie_embeddings=True,
)
OPT = OptConfig(name="adamw", lr=3e-4)

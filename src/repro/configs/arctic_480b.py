"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 PLUS parallel dense residual MLP.
[hf:Snowflake/snowflake-arctic-base]"""
from ..models.config import ModelConfig
from ..optim import OptConfig

CONFIG = ModelConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv=8,
    d_ff=4864, vocab=32000, group=(("attn", "moe+mlp"),), n_experts=128,
    top_k=2, act="silu", glu=True, norm="rms", pos="rope", rope_theta=1e4,
)
OPT = OptConfig(name="adafactor", lr=2e-4)

"""fmm2d — the paper's own "architecture": adaptive 2D FMM potential
evaluation (Goude & Engblom 2012), as a first-class config next to the
assigned LM pool.

Shapes are particle counts; tree depth follows the paper's calibration
eq. (5.2) with N_d = 45 (their GPU optimum). p = 17 -> TOL ~ 1e-6 (5.3).
"""
from ..core.config import FmmConfig, num_levels_for

N_D = 45          # particles per leaf box (paper Fig. 5.2, GPU optimum)
P_TERMS = 17      # expansion terms   (paper: tolerance ~1e-6)


def fmm_config(n: int, *, p: int = P_TERMS, dtype: str = "f32",
               nlevels: int | None = None) -> FmmConfig:
    lv = num_levels_for(n, N_D) if nlevels is None else nlevels
    return FmmConfig(n=n, nlevels=lv, p=p, theta=0.5, kernel="harmonic",
                     dtype=dtype, strong_cap=48, weak_cap=128)


FMM_SHAPES = {
    "n1m": 1 << 20,     # ~1M sources  (paper Fig. 5.8 scale)
    "n16m": 1 << 24,    # ~16M sources (beyond-paper, pod scale)
}

SMOKE = fmm_config(4096, p=8, nlevels=3)

"""rwkv6-1.6b [ssm] "Finch": 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536, data-dependent decay. [arXiv:2404.05892]"""
from ..models.config import ModelConfig
from ..optim import OptConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", n_layers=24, d_model=2048, n_heads=32, n_kv=32,
    d_ff=7168, vocab=65536, group=(("rwkv", "rwkv_cm"),), glu=False,
    act="relu", norm="ln", pos="none", rwkv_head_size=64,
)
OPT = OptConfig(name="adamw", lr=3e-4)

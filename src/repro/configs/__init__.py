"""Architecture registry: the 10 assigned archs + the paper's fmm2d.

``get_config(name)`` returns the exact published configuration;
``smoke_config(name)`` returns the reduced same-family variant used by the
CPU smoke tests (full configs are exercised only via the dry-run's
ShapeDtypeStructs — no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig
from .shapes import SHAPES, ShapeSpec, applicable

_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "arctic-480b": "arctic_480b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen2-72b": "qwen2_72b",
    "qwen3-0.6b": "qwen3_0_6b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "whisper-small": "whisper_small",
    "rwkv6-1.6b": "rwkv6_1_6b",
}

ARCH_NAMES = tuple(_MODULES)


def _module(name: str):
    key = name if name in _MODULES else name.replace("_", "-")
    if key not in _MODULES:
        key = {m: k for k, m in _MODULES.items()}.get(name, None)
    if key is None:
        raise KeyError(f"unknown arch {name}; know {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[key]}", __package__)


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_opt(name: str):
    return _module(name).OPT


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: tiny widths/depths, same block grammar."""
    cfg = get_config(name)
    n_kv = 4 if cfg.n_kv == cfg.n_heads else 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=2 * len(cfg.group),
        d_model=64,
        n_heads=4,
        n_kv=n_kv,
        d_head=16,
        d_ff=128,
        vocab=512,
        n_experts=min(4, cfg.n_experts) if cfg.n_experts else 0,
        top_k=min(2, cfg.top_k),
        enc_layers=2 if cfg.enc_layers else 0,
        n_audio_ctx=8,
        n_img_tokens=4 if cfg.n_img_tokens else 0,
        img_feat_dim=16,
        max_pos=128,
        rwkv_head_size=16,
        param_dtype="float32",
        compute_dtype="float32",
        attn_chunk=8,
        loss_chunk=16,
        remat="dots",
    )


__all__ = ["ARCH_NAMES", "get_config", "get_opt", "smoke_config",
           "SHAPES", "ShapeSpec", "applicable"]

"""Problem configurations for the FMM reproduction.

``fmm2d`` is the paper's own "architecture": calibrated tree depth
(eq. 5.2), expansion order and caps for 2D adaptive potential
evaluation. The LM architecture registry that shipped with the seed
scaffold was removed — it was dead weight unrelated to the paper.
"""
from .fmm2d import FMM_SHAPES, N_D, P_TERMS, SMOKE, fmm_config

__all__ = ["FMM_SHAPES", "N_D", "P_TERMS", "SMOKE", "fmm_config"]

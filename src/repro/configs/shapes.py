"""Assigned input-shape set (same 4 shapes for every LM arch).

  train_4k     train_step   seq 4096,   global batch 256
  prefill_32k  prefill      seq 32768,  global batch 32
  decode_32k   serve_step   one token, 32768-token KV cache, batch 128
  long_500k    serve_step   one token, 524288-token cache,  batch 1
               (sub-quadratic archs only — full-attention archs skip it,
                see DESIGN.md §4)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable(model_cfg, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape_name == "long_500k" and not model_cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k dense-KV decode is "
                       "the quadratic regime this shape excludes "
                       "(DESIGN.md §4)")
    return True, ""

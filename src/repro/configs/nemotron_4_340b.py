"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP (no GLU). [arXiv:2402.16819]"""
from ..models.config import ModelConfig
from ..optim import OptConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", n_layers=96, d_model=18432, n_heads=96, n_kv=8,
    d_ff=73728, vocab=256000, act="relu2", glu=False, norm="ln",
    pos="rope", rope_theta=1e4,
)
OPT = OptConfig(name="adafactor", lr=1e-4)

"""whisper-small [audio]: enc-dec, 12+12L d_model=768 12H (MHA kv=12)
d_ff=3072 vocab=51865. Conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (n_audio_ctx=1500 x feat). LayerNorm + GELU +
learned decoder positions (table extended to cover the assigned 32k decode
shape — a documented deviation from the 448 of the original).
[arXiv:2212.04356]"""
from ..models.config import ModelConfig
from ..optim import OptConfig

CONFIG = ModelConfig(
    name="whisper-small", arch="encdec", n_layers=12, enc_layers=12,
    d_model=768, n_heads=12, n_kv=12, d_ff=3072, vocab=51865,
    act="gelu", glu=False, norm="ln", pos="learned", max_pos=32768,
    qkv_bias=True, n_audio_ctx=1500, img_feat_dim=128,
)
OPT = OptConfig(name="adamw", lr=3e-4)

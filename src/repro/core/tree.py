"""Asymmetric adaptive FMM tree (paper §2, [7]).

Boxes are split at the particle *median*, twice per level, along the most
eccentric axis -> a perfectly balanced 4-ary pyramid. Because splits happen
at exact ranks, box b at level l owns the contiguous rank-slice
``[bounds[l][b], bounds[l][b+1])`` where the bounds depend only on (N, l):
a *static memory layout*, which is the property the whole GPU (here: TPU)
implementation is organized around.

GPU-paper -> TPU adaptation (DESIGN.md §2): the paper picks an approximate
pivot by sorting 32 samples per box (non-deterministic across runs due to
atomicAdd); we instead sort each segment by the chosen coordinate with a
single level-wide ``lexsort`` and cut at the exact median rank. This is
deterministic and keeps every leaf within +-1 particle of perfectly
balanced.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from .config import FmmConfig, level_bounds, segment_ids, split_bounds


class Tree(NamedTuple):
    """Sorted particles + per-level box geometry. All shapes static."""

    perm: jax.Array          # (N,) int32; sorted_field[i] corresponds to input index perm[i]
    z: jax.Array             # (N,) complex, rank-sorted positions
    q: jax.Array             # (N,) complex, rank-sorted strengths
    centers: tuple[jax.Array, ...]   # level l: (4**l,) complex
    radii: tuple[jax.Array, ...]     # level l: (4**l,) real


def _seg_minmax(v: jax.Array, sid: jax.Array, nseg: int):
    mn = jax.ops.segment_min(v, sid, num_segments=nseg, indices_are_sorted=True)
    mx = jax.ops.segment_max(v, sid, num_segments=nseg, indices_are_sorted=True)
    return mn, mx


def build_tree(z: jax.Array, q: jax.Array, cfg: FmmConfig) -> Tree:
    """Sort particles into the static pyramid layout and compute geometry."""
    rdt = cfg.real_dtype
    cdt = cfg.complex_dtype
    z = z.astype(cdt)
    q = q.astype(cdt)
    x = jnp.real(z).astype(rdt)
    y = jnp.imag(z).astype(rdt)
    perm = jnp.arange(cfg.n, dtype=jnp.int32)

    sb = split_bounds(cfg.n, 2 * cfg.nlevels)
    for s in range(2 * cfg.nlevels):
        nseg = 2**s
        sid = jnp.asarray(segment_ids(sb[s]))
        xmn, xmx = _seg_minmax(x, sid, nseg)
        ymn, ymx = _seg_minmax(y, sid, nseg)
        # split along the wider (more eccentric) axis of each box
        split_x = (xmx - xmn) >= (ymx - ymn)
        coord = jnp.where(split_x[sid], x, y)
        order = jnp.lexsort((coord, sid))
        x, y, perm = x[order], y[order], perm[order]

    z_sorted = (x + 1j * y).astype(cdt)
    q_sorted = q[perm]

    centers = []
    radii = []
    lb = level_bounds(cfg)
    for l in range(cfg.nlevels + 1):
        nseg = 4**l
        sid = jnp.asarray(segment_ids(lb[l]))
        xmn, xmx = _seg_minmax(x, sid, nseg)
        ymn, ymx = _seg_minmax(y, sid, nseg)
        cx = 0.5 * (xmn + xmx)
        cy = 0.5 * (ymn + ymx)
        centers.append((cx + 1j * cy).astype(cdt))
        # shrink-to-fit half-diagonal (conservative expansion radius)
        radii.append((0.5 * jnp.hypot(xmx - xmn, ymx - ymn)).astype(rdt))

    return Tree(perm=perm, z=z_sorted, q=q_sorted,
                centers=tuple(centers), radii=tuple(radii))


def leaf_particle_index(cfg: FmmConfig) -> np.ndarray:
    """(4**L, n_max) int32 gather map leaf-box -> particle ranks, -1 padded.

    Purely static (depends only on N and nlevels) — this is the paper's
    "static layout of memory" made literal: the map is a numpy constant
    baked into the compiled program.
    """
    lb = level_bounds(cfg)[-1]
    sizes = np.diff(lb)
    n_max = int(sizes.max())
    nbox = len(sizes)
    idx = np.full((nbox, n_max), -1, dtype=np.int32)
    for b in range(nbox):
        idx[b, : sizes[b]] = np.arange(lb[b], lb[b + 1], dtype=np.int32)
    return idx


def leaf_ids(cfg: FmmConfig) -> np.ndarray:
    """(N,) int32: leaf box owning each rank."""
    return segment_ids(level_bounds(cfg)[-1])

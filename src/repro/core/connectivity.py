"""Theta-criterion connectivity (paper §2, eq. (2.1)).

Per level l, every box carries a *directed* strong list and a *directed*
weak (M2L) list, padded to static caps — the paper's §4.3 design: the GPU
(here: TPU) version deliberately duplicates symmetric pairs so each box's
interactions can be computed independently without atomics; the paper
measures the cost of this at ~1% of runtime.

Candidates for box b at level l are exactly the children of the strong set
of b's parent (paper §2); each candidate is classified by

    well-separated(b, c)  <=>  R + theta*r <= theta*d,
    R = max(r_b, r_c), r = min(r_b, r_c), d = |z_b - z_c|.

At the leaf level, strong pairs are re-tested with r/R roles swapped
(Carrier-Greengard optimization, paper §2): passing pairs become P2L (the
larger box's particles shift directly into the smaller box's local
expansion) / M2P (the smaller box's multipole is evaluated directly at the
larger box's points) instead of P2P.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import FmmConfig
from .tree import Tree

_INT_MAX = jnp.iinfo(jnp.int32).max


class Connectivity(NamedTuple):
    strong: tuple[jax.Array, ...]   # level l: (4**l, strong_cap) int32, -1 pad
    weak: tuple[jax.Array, ...]     # level l: (4**l, weak_cap)
    p2p: jax.Array                  # leaf: (4**L, strong_cap)
    p2l: jax.Array                  # leaf: (4**L, strong_cap)
    m2p: jax.Array                  # leaf: (4**L, strong_cap)
    overflow: jax.Array             # scalar int32; 0 iff no list overflowed


def _compact(vals: jax.Array, mask: jax.Array, cap: int):
    """Row-compact masked entries to the front, pad with -1, clip to cap.

    Returns (compacted (B, cap), overflow (B,)) where overflow counts
    entries dropped by the cap.
    """
    key = jnp.where(mask, vals, _INT_MAX)
    srt = jnp.sort(key, axis=-1)
    count = mask.sum(axis=-1)
    kept = srt[..., :cap]
    out = jnp.where(kept == _INT_MAX, -1, kept)
    overflow = jnp.maximum(count - cap, 0)
    return out, overflow


def _theta_masks(cb, rb, cc, rc, valid, theta):
    d = jnp.abs(cb[:, None] - cc)
    big = jnp.maximum(rb[:, None], rc)
    small = jnp.minimum(rb[:, None], rc)
    wellsep = (big + theta * small) <= (theta * d)
    return valid & wellsep, valid & ~wellsep


def build_connectivity(tree: Tree, cfg: FmmConfig) -> Connectivity:
    theta = cfg.theta
    S, W = cfg.strong_cap, cfg.weak_cap
    L = cfg.nlevels

    strong = [jnp.zeros((1, S), jnp.int32).at[:, 1:].set(-1)]  # root: self
    weak = [jnp.full((1, W), -1, jnp.int32)]
    overflow = jnp.zeros((), jnp.int32)

    for l in range(1, L + 1):
        nb = 4**l
        box = jnp.arange(nb, dtype=jnp.int32)
        parent_strong = strong[l - 1][box // 4]                 # (nb, S)
        pvalid = parent_strong >= 0
        cand = (jnp.where(pvalid, parent_strong, 0)[:, :, None] * 4
                + jnp.arange(4, dtype=jnp.int32)).reshape(nb, 4 * S)
        valid = jnp.repeat(pvalid, 4, axis=-1)

        cb, rb = tree.centers[l], tree.radii[l]
        cc = cb[cand]
        rc = jnp.where(valid, rb[cand], 0.0)
        cc = jnp.where(valid, cc, 0.0)
        weak_mask, strong_mask = _theta_masks(cb, rb, cc, rc, valid, theta)

        s_l, s_of = _compact(cand, strong_mask, S)
        w_l, w_of = _compact(cand, weak_mask, W)
        strong.append(s_l)
        weak.append(w_l)
        overflow = jnp.maximum(overflow,
                               jnp.maximum(s_of.max(), w_of.max()).astype(jnp.int32))

    # ---- leaf-level swapped-theta reclassification -------------------------
    st = strong[L]
    valid = st >= 0
    idx = jnp.where(valid, st, 0)
    cb, rb = tree.centers[L], tree.radii[L]
    cc = jnp.where(valid, cb[idx], 0.0)
    rc = jnp.where(valid, rb[idx], 0.0)
    d = jnp.abs(cb[:, None] - cc)
    big = jnp.maximum(rb[:, None], rc)
    small = jnp.minimum(rb[:, None], rc)
    if cfg.use_p2l_m2p:
        swapped = (small + theta * big) <= (theta * d)   # roles interchanged
        p2l_mask = valid & swapped & (rc > rb[:, None])  # source box larger
        m2p_mask = valid & swapped & (rc < rb[:, None])  # source box smaller
        p2p_mask = valid & ~(p2l_mask | m2p_mask)
    else:
        p2l_mask = jnp.zeros_like(valid)
        m2p_mask = jnp.zeros_like(valid)
        p2p_mask = valid
    p2p, of1 = _compact(st, p2p_mask, S)
    p2l, of2 = _compact(st, p2l_mask, S)
    m2p, of3 = _compact(st, m2p_mask, S)
    overflow = jnp.maximum(
        overflow,
        jnp.maximum(jnp.maximum(of1.max(), of2.max()), of3.max()).astype(jnp.int32),
    )

    return Connectivity(strong=tuple(strong), weak=tuple(weak),
                        p2p=p2p, p2l=p2l, m2p=m2p, overflow=overflow)


def connectivity_stats(conn: Connectivity) -> dict:
    """Interaction counts per phase (for the paper's Table 5.1 analysis)."""
    out = {
        "m2l_pairs": int(sum(int((w >= 0).sum()) for w in conn.weak)),
        "p2p_pairs": int((conn.p2p >= 0).sum()),
        "p2l_pairs": int((conn.p2l >= 0).sum()),
        "m2p_pairs": int((conn.m2p >= 0).sum()),
        "strong_max": max(int((s >= 0).sum(-1).max()) for s in conn.strong),
        "weak_max": max(int((w >= 0).sum(-1).max()) for w in conn.weak),
        "overflow": int(conn.overflow),
    }
    return out

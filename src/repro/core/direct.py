"""Direct O(N^2) evaluation (paper eq. (1.1)/(1.2)) — oracle + baseline.

``direct_potential`` is the chunked jnp implementation used both as the
accuracy oracle for the FMM and as the break-even baseline of Fig. 5.5.
Coincident points are excluded, matching the ``x_j != y_i`` convention of
eq. (1.2) (and the FMM's own P2P convention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("kernel", "chunk"))
def direct_potential(z_eval: jax.Array, z_src: jax.Array, q: jax.Array,
                     kernel: str = "harmonic", chunk: int = 2048) -> jax.Array:
    """Phi(y_i) = sum_{x_j != y_i} G(y_i, x_j)."""
    n = z_eval.shape[0]
    pad = (-n) % chunk
    ze = jnp.pad(z_eval, (0, pad))

    def body(carry, zc):
        diff = z_src[None, :] - zc[:, None]
        ok = diff != 0
        safe = jnp.where(ok, diff, 1.0)
        if kernel == "harmonic":
            c = jnp.where(ok, q[None, :] / safe, 0.0)
        else:
            c = jnp.where(ok, q[None, :] * jnp.log(-safe), 0.0)
        return carry, c.sum(axis=-1)

    _, phi = jax.lax.scan(body, 0, ze.reshape(-1, chunk))
    return phi.reshape(-1)[:n]


def direct_potential_numpy(z_eval, z_src, q, kernel: str = "harmonic"):
    """float64 numpy oracle (independent of jax) for small-N tests."""
    import numpy as np

    ze = np.asarray(z_eval, dtype=np.complex128)
    zs = np.asarray(z_src, dtype=np.complex128)
    qs = np.asarray(q, dtype=np.complex128)
    out = np.zeros_like(ze)
    for i in range(len(ze)):
        d = zs - ze[i]
        ok = d != 0
        if kernel == "harmonic":
            out[i] = (qs[ok] / d[ok]).sum()
        else:
            out[i] = (qs[ok] * np.log(-d[ok])).sum()
    return out


def rel_error_inf(phi, phi_ref) -> float:
    """Paper eq. (5.3): || (phi - ref) / ref ||_inf  (on nonzero refs)."""
    import numpy as np

    phi = np.asarray(phi)
    ref = np.asarray(phi_ref)
    ok = np.abs(ref) > 0
    return float(np.max(np.abs((phi[ok] - ref[ok]) / ref[ok])))

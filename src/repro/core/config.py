"""Configuration for the adaptive FMM (Goude & Engblom 2012).

Everything here is *static* under jit: FmmConfig is a frozen, hashable
dataclass passed as a static argument, so tree offsets, level sizes and
list caps are compile-time constants — the static-memory-layout property
of the paper's asymmetric adaptivity, carried over verbatim.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

import numpy as np

Kernel = Literal["harmonic", "log"]


def num_levels_for(n: int, n_d: int) -> int:
    """Paper eq. (5.2): N_l = ceil(0.5*log2(5/8 * N/N_d)).

    ``n_d`` is the desired number of sources per finest-level box (the
    paper's calibration finds n_d≈45 on GPU, ≈35 on CPU).
    """
    if n <= max(n_d, 1):
        return 0
    return max(0, math.ceil(0.5 * math.log2(5.0 / 8.0 * n / n_d)))


@dataclasses.dataclass(frozen=True)
class FmmConfig:
    """Static FMM problem description.

    Attributes:
      n: number of source points (== evaluation points in the kernel path).
      nlevels: tree depth; level l has 4**l boxes; leaves at ``nlevels``.
      p: number of expansion terms (paper's p; p=17 -> TOL ~ 1e-6 at theta=1/2).
      theta: separation parameter of the theta-criterion (2.1).
      kernel: "harmonic" (paper eq. (5.1), a0=0) or "log".
      strong_cap / weak_cap: padded per-box list capacities (checked at build).
      dtype: "f32" or "f64" (f64 requires jax x64 mode; TPU target uses f32).
      m2l_chunk: pair-chunk size for the level M2L sweep (memory knob).
      translations: "mxu" (scaled constant-matrix GEMM form; TPU-native) or
        "horner" (the paper's Algorithms 3.4b/3.5/3.6, kept as the faithful
        baseline).
      use_p2l_m2p: enable the leaf-level swapped-theta reclassification
        (paper §2: Carrier-Greengard optimization). Off -> plain P2P.
      tile_boxes: target boxes per Pallas kernel block (DESIGN.md §2). The
        P2P/M2L/L2P kernels process (tile_boxes, n_pad)/(tile_boxes, P)
        blocks per grid step — the TPU analogue of the paper's one-block-
        per-box shared-memory staging, widened to fill the 8x128 vector
        registers / the MXU. Autotunable (solver.tune); correctness is
        tile-independent.
      stage_width: interaction-list slots staged per grid step. Each staged
        slot adds one scalar-prefetch-indexed (1, n_pad) source tile per
        target box, so a step DMAs tile_boxes*stage_width source rows and
        amortizes grid overhead across them (double-buffered by Pallas).
    """

    n: int
    nlevels: int
    p: int = 17
    theta: float = 0.5
    kernel: Kernel = "harmonic"
    strong_cap: int = 48
    weak_cap: int = 0   # 0 -> 4*strong_cap (structural bound: weak
    #                     candidates are children of the parent's strong set)
    dtype: str = "f32"
    m2l_chunk: int = 16
    translations: str = "mxu"
    use_p2l_m2p: bool = True
    tile_boxes: int = 8
    stage_width: int = 1

    # -- derived static properties ------------------------------------------
    @property
    def nboxes(self) -> int:
        return 4**self.nlevels

    @property
    def real_dtype(self):
        return np.float64 if self.dtype == "f64" else np.float32

    @property
    def complex_dtype(self):
        return np.complex128 if self.dtype == "f64" else np.complex64

    def level_size(self, l: int) -> int:
        return 4**l

    def __post_init__(self):
        if self.weak_cap == 0:
            object.__setattr__(self, "weak_cap", 4 * self.strong_cap)
        if self.nlevels < 0:
            raise ValueError("nlevels must be >= 0")
        if self.p < 1:
            raise ValueError("p must be >= 1")
        if not (0.0 < self.theta < 1.0):
            raise ValueError("theta in (0,1)")
        if self.tile_boxes < 1 or self.stage_width < 1:
            raise ValueError("tile_boxes and stage_width must be >= 1")
        if self.tile_boxes * self.stage_width > 128:
            raise ValueError(
                "tile_boxes * stage_width > 128: each staged source row is "
                "one kernel operand; this tiling would not fit VMEM")
        if self.n < 4**self.nlevels:
            raise ValueError(
                f"n={self.n} < 4**nlevels={4**self.nlevels}: every leaf needs "
                "at least one particle (pick fewer levels)"
            )


def split_bounds(n: int, nsplits: int) -> list[np.ndarray]:
    """Static rank boundaries after each binary split.

    Returns a list of length ``nsplits+1``; entry ``s`` is an int64 array of
    ``2**s + 1`` rank boundaries. A segment ``[a, b)`` splits at
    ``a + ceil((b-a)/2)`` — the median split of the paper, but at exact,
    deterministic ranks (see DESIGN.md §7.2).
    """
    out = [np.array([0, n], dtype=np.int64)]
    cur = out[0]
    for _ in range(nsplits):
        mids = cur[:-1] + (cur[1:] - cur[:-1] + 1) // 2
        nxt = np.empty(2 * len(cur) - 1, dtype=np.int64)
        nxt[0::2] = cur
        nxt[1::2] = mids
        out.append(nxt)
        cur = nxt
    return out


def segment_ids(bounds: np.ndarray) -> np.ndarray:
    """(n,) int32 mapping a particle rank to its segment index."""
    sizes = np.diff(bounds)
    return np.repeat(np.arange(len(sizes), dtype=np.int32), sizes)


def level_bounds(cfg: FmmConfig) -> list[np.ndarray]:
    """Rank boundaries of the 4**l boxes at every level l=0..nlevels."""
    sb = split_bounds(cfg.n, 2 * cfg.nlevels)
    return [sb[2 * l] for l in range(cfg.nlevels + 1)]


def leaf_sizes(cfg: FmmConfig) -> np.ndarray:
    lb = level_bounds(cfg)[-1]
    return np.diff(lb).astype(np.int32)


def max_leaf_size(cfg: FmmConfig) -> int:
    return int(leaf_sizes(cfg).max())

"""Multipole/local expansions and translation operators (2D, complex plane).

Conventions (paper §2, eqs (2.2)-(2.3)):

  multipole around z0:  M(z) = a_0 log(z - z0) + sum_{j=1..p} a_j (z - z0)^{-j}
  local     around z0:  L(z) = sum_{j=0..p} b_j (z - z0)^j

Kernels:
  "harmonic": G(z, x) = q / (x - z)          (paper eq. (5.1); a_0 = 0)
  "log":      G(z, x) = q * log(z - x)       (potential is Re-valued;
                                              branch cuts only affect Im)

Two implementations of each translation:

  *_horner : the paper's Algorithms 3.4(b) / 3.5 / 3.6 — scaled
             Pascal-triangle accumulation, no binomial tables. Kept as the
             paper-faithful baseline and as the oracle for the Pallas
             kernels.
  *_apply  : TPU-native factorization  diag-scale -> constant (p+1)^2
             matrix multiply -> diag-scale.  The constant matrices are
             binomial (Pascal / Hankel) tables; the per-shift work becomes
             a batched GEMM on the MXU.  Mathematically identical.

All ops are batched over arbitrary leading axes; coefficient arrays have
shape (..., p+1) and shift offsets shape (...).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# constant binomial matrices (numpy, float64; cast at use site)
# --------------------------------------------------------------------------

def _binom_table(n: int) -> np.ndarray:
    c = np.zeros((n + 1, n + 1))
    c[:, 0] = 1.0
    for i in range(1, n + 1):
        for j in range(1, i + 1):
            c[i, j] = c[i - 1, j - 1] + c[i - 1, j]
    return c


def m2m_matrix(p: int) -> np.ndarray:
    """A with b_hat = A @ a_hat;  a_hat_j = a_j t^-j, b_hat_l = b_l t^-l,
    t = z_child - z_parent.  A[l,j] = C(l-1, j-1) for 1<=j<=l; the a_0
    (log-source) column is A[l,0] = -1/l; A[0,0] = 1."""
    c = _binom_table(p)
    a = np.zeros((p + 1, p + 1))
    a[0, 0] = 1.0
    for l in range(1, p + 1):
        a[l, 0] = -1.0 / l
        for j in range(1, l + 1):
            a[l, j] = c[l - 1, j - 1]
    return a


def m2l_matrix(p: int) -> np.ndarray:
    """H with b_hat = H @ a_hat; a_hat_k = a_k r^-k, b_l = b_hat_l (-1)^l r^-l
    (l>=1), b_0 = b_hat_0 + a_0 log r;  r = z_target - z_source.
    H[l,k] = C(l+k-1, k-1) for l>=1,k>=1; H[0,k]=1 (k>=1); H[l,0] = -1/l."""
    c = _binom_table(2 * p)
    h = np.zeros((p + 1, p + 1))
    for k in range(1, p + 1):
        h[0, k] = 1.0
    for l in range(1, p + 1):
        h[l, 0] = -1.0 / l
        for k in range(1, p + 1):
            h[l, k] = c[l + k - 1, k - 1]
    return h


def l2l_matrix(p: int) -> np.ndarray:
    """B with c_hat = B @ b_hat; b_hat_j = b_j s^j, c_hat_l = c_l s^l,
    s = z_child - z_parent.  B[l,j] = C(j, l) for j>=l."""
    c = _binom_table(p)
    b = np.zeros((p + 1, p + 1))
    for l in range(p + 1):
        for j in range(l, p + 1):
            b[l, j] = c[j, l]
    return b


# --------------------------------------------------------------------------
# power helpers
# --------------------------------------------------------------------------

def pows(r: jax.Array, p: int) -> jax.Array:
    """[r^0, r^1, ..., r^p] stacked on a new trailing axis."""
    out = [jnp.ones_like(r)]
    for _ in range(p):
        out.append(out[-1] * r)
    return jnp.stack(out, axis=-1)


def inv_pows(r: jax.Array, p: int) -> jax.Array:
    return pows(1.0 / r, p)


# --------------------------------------------------------------------------
# matrix ("mxu") forms
# --------------------------------------------------------------------------

def m2m_apply(a: jax.Array, t: jax.Array, mat: jax.Array) -> jax.Array:
    """Shift multipole coefficients by t = z_child - z_parent."""
    p = a.shape[-1] - 1
    ti = inv_pows(t, p)
    a_hat = a * ti
    b_hat = jnp.einsum("...j,lj->...l", a_hat, mat)
    return b_hat * pows(t, p)


def m2l_apply(a: jax.Array, r: jax.Array, mat: jax.Array) -> jax.Array:
    """Multipole around z_source -> local around z_target; r = z_t - z_s."""
    p = a.shape[-1] - 1
    a_hat = a * inv_pows(r, p)
    b_hat = jnp.einsum("...k,lk->...l", a_hat, mat)
    b = b_hat * inv_pows(-r, p)
    # log-source correction on the constant term
    return b.at[..., 0].add(a[..., 0] * jnp.log(r))


def l2l_apply(b: jax.Array, s: jax.Array, mat: jax.Array) -> jax.Array:
    """Shift local coefficients by s = z_child - z_parent."""
    p = b.shape[-1] - 1
    b_hat = b * pows(s, p)
    c_hat = jnp.einsum("...j,lj->...l", b_hat, mat)
    return c_hat * inv_pows(s, p)


# --------------------------------------------------------------------------
# paper-faithful scaled-Horner forms (Algorithms 3.4(b), 3.5, 3.6)
# --------------------------------------------------------------------------

def m2m_horner(a: jax.Array, t: jax.Array) -> jax.Array:
    """Algorithm 3.4(b). t = z_child - z_parent (paper's r)."""
    p = a.shape[-1] - 1
    rinv = 1.0 / t
    c = [a[..., j] for j in range(p + 1)]
    w = jnp.ones_like(t)
    for j in range(1, p + 1):            # pre-scale: a_j /= r^j
        w = w * rinv
        c[j] = c[j] * w
    for k in range(p, 1, -1):            # Pascal accumulation (sequential j)
        for j in range(k, p + 1):
            c[j] = c[j] + c[j - 1]
    w = jnp.ones_like(t)
    out = [c[0]]
    for j in range(1, p + 1):            # post-scale + log-source correction
        w = w * t
        out.append((c[j] - c[0] / j) * w)
    return jnp.stack(out, axis=-1)


def l2l_horner(b: jax.Array, s: jax.Array) -> jax.Array:
    """Algorithm 3.5. Paper's r = z_parent - z_child = -s."""
    p = b.shape[-1] - 1
    r = -s
    c = [b[..., j] for j in range(p + 1)]
    w = jnp.ones_like(r)
    for j in range(1, p + 1):            # pre-scale: b_j *= r^j
        w = w * r
        c[j] = c[j] * w
    for k in range(p + 1):               # inner loop is order-independent
        for j in range(p - k, p):
            c[j] = c[j] - c[j + 1]
    w = jnp.ones_like(r)
    out = [c[0]]
    for j in range(1, p + 1):            # post-scale: b_j /= r^j
        w = w * r
        out.append(c[j] / w)
    return jnp.stack(out, axis=-1)


def m2l_horner(a: jax.Array, r: jax.Array) -> jax.Array:
    """Algorithm 3.6. r = z_target - z_source (paper's z_o - z_i).

    Note on signs: the published pseudocode's (-1)^j factors assume the
    opposite shift direction (r = z_i - z_o). With our r the map reduces to
    the all-positive Pascal chain below, with the alternating sign folded
    into the (-r)^-j post-scale. Verified identical to the binomial-matrix
    oracle ``m2l_apply`` (see tests/test_expansions.py): the two reductions
    compute the L·Lᵀ factorization of the Hankel matrix C(l+k-1, k-1)
    (Vandermonde identity), which is the combination the paper notes it had
    "not seen described elsewhere".
    """
    p = a.shape[-1] - 1
    rinv = 1.0 / r
    b = [jnp.zeros_like(a[..., 0]) for _ in range(p + 1)]
    w = jnp.ones_like(r)
    for j in range(1, p + 1):            # b_{j-1} := a_j / r^j
        w = w * rinv
        b[j - 1] = a[..., j] * w
    # first reduction (L2L-style; inner loop order-independent): L^T
    for k in range(2, p + 1):
        for j in range(p - k, p):
            b[j] = b[j] + b[j + 1]
    # second reduction (M2M-style; inner loop sequential): L
    for k in range(p, 0, -1):
        for j in range(k, p + 1):
            b[j] = b[j] + b[j - 1]
    a0 = a[..., 0]
    w = jnp.ones_like(r)
    out = [b[0] + a0 * jnp.log(r)]
    for j in range(1, p + 1):
        w = w * (-rinv)
        out.append((b[j] - a0 / j) * w)
    return jnp.stack(out, axis=-1)


# --------------------------------------------------------------------------
# direct expansion constructors / evaluators (single box; used by tests,
# refs and the pointwise P2M/P2L/L2P/M2P sweeps in fmm.py)
# --------------------------------------------------------------------------

def p2m_single(x: jax.Array, q: jax.Array, z0: jax.Array, p: int,
               kernel: str) -> jax.Array:
    """Multipole coefficients of sources x (strengths q) around z0."""
    t = x - z0
    if kernel == "harmonic":
        # q/(x - z) = -q * sum_k (x-z0)^k (z-z0)^-(k+1)  =>  a_j = -sum q t^(j-1)
        coeffs = [jnp.sum(q) * 0]  # a_0 = 0
        w = q
        for _ in range(p):
            coeffs.append(-jnp.sum(w))
            w = w * t
        return jnp.stack(coeffs, axis=-1)
    elif kernel == "log":
        # q log(z - x): a_0 = sum q; a_j = -sum q t^j / j
        coeffs = [jnp.sum(q)]
        w = q
        for j in range(1, p + 1):
            w = w * t
            coeffs.append(-jnp.sum(w) / j)
        return jnp.stack(coeffs, axis=-1)
    raise ValueError(kernel)


def p2l_single(x: jax.Array, q: jax.Array, z0: jax.Array, p: int,
               kernel: str) -> jax.Array:
    """Local coefficients around z0 from *far* sources x (strengths q)."""
    w = 1.0 / (x - z0)
    if kernel == "harmonic":
        # q/(x - z) = q sum_l (z-z0)^l (x-z0)^-(l+1)  =>  b_l = sum q w^(l+1)
        pw = q * w
        coeffs = []
        for _ in range(p + 1):
            coeffs.append(jnp.sum(pw))
            pw = pw * w
        return jnp.stack(coeffs, axis=-1)
    elif kernel == "log":
        # q log(z - x) = q log(z0 - x) - q sum_l ((z-z0) w)^l / l
        coeffs = [jnp.sum(q * jnp.log(z0 - x))]
        pw = q * w
        for l in range(1, p + 1):
            coeffs.append(-jnp.sum(pw) / l)
            pw = pw * w
        return jnp.stack(coeffs, axis=-1)
    raise ValueError(kernel)


def eval_multipole(a: jax.Array, z0: jax.Array, z: jax.Array) -> jax.Array:
    """M(z) for coefficients a around z0 (Horner in 1/(z-z0))."""
    p = a.shape[-1] - 1
    w = 1.0 / (z - z0)
    acc = jnp.zeros_like(z) + a[..., p]
    for j in range(p - 1, 0, -1):
        acc = acc * w + a[..., j]
    acc = acc * w
    return acc + a[..., 0] * jnp.log(z - z0)


def eval_local(b: jax.Array, z0: jax.Array, z: jax.Array) -> jax.Array:
    """L(z) for coefficients b around z0 (Horner)."""
    p = b.shape[-1] - 1
    t = z - z0
    acc = jnp.zeros_like(z) + b[..., p]
    for j in range(p - 1, -1, -1):
        acc = acc * t + b[..., j]
    return acc


# --------------------------------------------------------------------------
# radius-normalized forms (beyond-paper numerical upgrade, DESIGN.md §2/§7)
#
# Coefficients are stored scaled by the owning box's effective radius:
#   multipole:  a~_j = a_j * rho^-j      local:  b~_l = b_l * rho^l
# Every translation then only multiplies by bounded ratios (|t|/rho_parent,
# rho_child/rho_parent, rho/r with r the pair separation), so no power of a
# small length is ever inverted — the plain scaled forms overflow f32 for
# any tree deeper than ~5 levels (|t|^-p with |t| ~ 2^-depth) and f64 in
# degenerate shrink-to-fit geometries. M2L keeps the constant Hankel matrix
# (MXU path); M2M/L2L become multiplier-Horner passes (they are <1% of the
# work, paper Table 5.1).
# --------------------------------------------------------------------------

def p2m_norm(w: jax.Array, q: jax.Array, inv_rho, p: int, kernel: str,
             seg_sum) -> jax.Array:
    """Normalized P2M. w = (x - z0)/rho per particle; seg_sum reduces a
    per-particle vector to per-box. Returns (nbox, p+1) scaled coeffs."""
    coeffs = []
    if kernel == "harmonic":
        coeffs.append(seg_sum(q) * 0)
        pw = q
        for _ in range(p):
            coeffs.append(-seg_sum(pw) * inv_rho)
            pw = pw * w
    else:
        coeffs.append(seg_sum(q))
        pw = q
        for j in range(1, p + 1):
            pw = pw * w
            coeffs.append(-seg_sum(pw) / j)
    return jnp.stack(coeffs, axis=-1)


def m2m_norm(a: jax.Array, u: jax.Array, ratio: jax.Array) -> jax.Array:
    """Normalized M2M: u = t/rho_parent, ratio = rho_child/rho_parent."""
    p = a.shape[-1] - 1
    c = [a[..., 0]]
    w = jnp.ones_like(ratio)
    for j in range(1, p + 1):
        w = w * ratio
        c.append(a[..., j] * w)
    for k in range(p, 1, -1):            # Pascal pass with multiplier u
        for j in range(k, p + 1):
            c[j] = c[j] + u * c[j - 1]
    w = jnp.ones_like(u)
    out = [c[0]]
    for j in range(1, p + 1):            # log-source correction
        w = w * u
        out.append(c[j] - c[0] * w / j)
    return jnp.stack(out, axis=-1)


def l2l_norm(b: jax.Array, v: jax.Array, ratio: jax.Array) -> jax.Array:
    """Normalized L2L: v = s/rho_parent, ratio = rho_child/rho_parent."""
    p = b.shape[-1] - 1
    c = [b[..., j] for j in range(p + 1)]
    for k in range(p + 1):               # suffix passes with multiplier v
        for j in range(p - k, p):
            c[j] = c[j] + v * c[j + 1]
    w = jnp.ones_like(ratio)
    out = [c[0]]
    for l in range(1, p + 1):
        w = w * ratio
        out.append(c[l] * w)
    return jnp.stack(out, axis=-1)


def m2l_norm(a: jax.Array, r: jax.Array, rho_s: jax.Array,
             rho_t: jax.Array, mat: jax.Array) -> jax.Array:
    """Normalized M2L (constant Hankel matrix preserved — the MXU path).

    r = z_target - z_source; all scale vectors are powers of rho/r ratios
    bounded by the theta-criterion."""
    p = a.shape[-1] - 1
    pre = pows(rho_s / r, p)
    pre = pre.at[..., 0].set(1.0)        # a~_0 = a_0 (log strength)
    a_hat = a * pre
    b_hat = jnp.einsum("...k,lk->...l", a_hat, mat)
    b = b_hat * pows(-rho_t / r, p)
    return b.at[..., 0].add(a[..., 0] * jnp.log(r))


def m2l_norm_horner(a: jax.Array, r: jax.Array, rho_s: jax.Array,
                    rho_t: jax.Array) -> jax.Array:
    """Normalized Algorithm 3.6 (positive-Pascal chain, cf. m2l_horner)."""
    p = a.shape[-1] - 1
    ws = rho_s / r
    b = [jnp.zeros_like(a[..., 0]) for _ in range(p + 1)]
    w = jnp.ones_like(r)
    for j in range(1, p + 1):
        w = w * ws
        b[j - 1] = a[..., j] * w
    for k in range(2, p + 1):
        for j in range(p - k, p):
            b[j] = b[j] + b[j + 1]
    for k in range(p, 0, -1):
        for j in range(k, p + 1):
            b[j] = b[j] + b[j - 1]
    a0 = a[..., 0]
    wt = -rho_t / r
    w = jnp.ones_like(r)
    out = [b[0] + a0 * jnp.log(r)]
    for j in range(1, p + 1):
        w = w * wt
        out.append((b[j] - a0 / j) * w)
    return jnp.stack(out, axis=-1)

"""Adaptive fast multipole method (Goude & Engblom 2012) — TPU-native JAX.

Public API:
  FmmConfig, num_levels_for        — problem description / calibration
  build_tree, build_connectivity   — topological phase
  fmm_potential                    — end-to-end evaluation (jit)
  direct_potential                 — O(N^2) oracle / baseline
"""
from .config import FmmConfig, num_levels_for, max_leaf_size
from .topology import (Tree, build_tree, leaf_particle_index, leaf_ids,
                       Connectivity, MARGIN_CLASSES, build_connectivity,
                       connectivity_stats)
from .fmm import (FmmPlan, Health, HEALTH_CLASSES, fmm_build, fmm_evaluate,
                  fmm_potential, fmm_potential_checked,
                  fmm_potential_with_stats, health_of, p2m,
                  upward, downward, l2p)
from .direct import direct_potential, direct_potential_numpy, rel_error_inf

__all__ = [
    "FmmConfig", "num_levels_for", "max_leaf_size",
    "Tree", "build_tree", "leaf_particle_index", "leaf_ids",
    "Connectivity", "MARGIN_CLASSES", "build_connectivity",
    "connectivity_stats",
    "FmmPlan", "Health", "HEALTH_CLASSES", "fmm_build", "fmm_evaluate",
    "fmm_potential", "fmm_potential_checked", "fmm_potential_with_stats",
    "health_of", "p2m", "upward", "downward", "l2p",
    "direct_potential", "direct_potential_numpy", "rel_error_inf",
]

"""The adaptive FMM pipeline (paper §3.3) as a single jit-able function.

Phases (paper naming):
  topological: build_tree (sort) + build_connectivity (connect)
  upward:      P2M (+ P2L) , M2M
  downward:    M2L , L2L
  evaluation:  L2P (+ M2P) , P2P

The per-phase functions are exposed individually so the benchmark harness
can time them (Table 5.1 / Figs 5.1, 5.3, 5.7) and so the Pallas kernels
in ``repro.kernels`` can replace the hot ones (P2P, M2L) one at a time.

Every shape is static given ``FmmConfig``; there is no data-dependent
control flow — the adaptivity lives entirely in the *contents* of the
padded interaction lists, which is the paper's central design point and
exactly what pjit/TPU want.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import expansions as E
from .config import FmmConfig
from .topology import (MARGIN_CLASSES, Connectivity, Tree,
                       build_connectivity, build_tree, leaf_ids,
                       leaf_particle_index)


class FmmPlan(NamedTuple):
    """Static constants + built tree/connectivity for one evaluation."""

    tree: Tree
    conn: Connectivity


#: Order of the per-class entries in ``Health.margins`` (the
#: connectivity's ``MARGIN_CLASSES``, re-exported at the pipeline level).
HEALTH_CLASSES = MARGIN_CLASSES


class Health(NamedTuple):
    """In-graph health plane of one evaluation (DESIGN.md §9).

    A handful of scalars computed *inside* the compiled pipeline, so
    validated entry points (``FmmSolver.apply_checked``, the guarded
    ladder) read execution health with ONE ``device_get`` on the launch
    they already ran — no second eager topology build:

      margins           (5,) int32, ``HEALTH_CLASSES`` order — slots left
                        on the fullest interaction list per class;
                        negative = that many entries were silently
                        dropped (the answer is wrong)
      overflow          () int32 — max dropped-entry count (0 = healthy)
      nonfinite_input   () bool — any NaN/Inf in z or q
      nonfinite_output  () bool — any NaN/Inf in phi
    """

    margins: jax.Array
    overflow: jax.Array
    nonfinite_input: jax.Array
    nonfinite_output: jax.Array


def _any_nonfinite(*arrays: jax.Array) -> jax.Array:
    flag = jnp.asarray(False)
    for a in arrays:
        flag = flag | ~jnp.all(jnp.isfinite(a))
    return flag


def health_of(plan: FmmPlan, z: jax.Array, q: jax.Array,
              phi: jax.Array) -> Health:
    """Assemble the health plane for an evaluation of ``plan`` on
    (z, q) that produced ``phi``. Pure graph ops — jit/vmap-safe."""
    return Health(margins=plan.conn.margins,
                  overflow=plan.conn.overflow,
                  nonfinite_input=_any_nonfinite(z, q),
                  nonfinite_output=_any_nonfinite(phi))


def effective_radii(tree: Tree, cfg: FmmConfig) -> list[jax.Array]:
    """Per-level normalization radii: the box radius floored at 1e-6 of the
    level maximum (point-like boxes would otherwise produce 0/0 ratios).

    All expansions are stored radius-normalized (a~_j = a_j rho^-j,
    b~_l = b_l rho^l): translations then multiply only bounded ratios,
    which is what makes deep trees work in f32 (the TPU dtype) — see
    expansions.py."""
    out = []
    for l in range(cfg.nlevels + 1):
        r = tree.radii[l]
        out.append(jnp.maximum(r, 1e-6 * jnp.max(r) + 1e-300))
    return out


# ---------------------------------------------------------------------------
# upward phase
# ---------------------------------------------------------------------------

def p2m(tree: Tree, cfg: FmmConfig, rho=None) -> jax.Array:
    """Leaf multipole expansions, radius-normalized; (4**L, p+1) complex."""
    nb = cfg.nboxes
    lid = jnp.asarray(leaf_ids(cfg))
    if rho is None:
        rho = effective_radii(tree, cfg)[cfg.nlevels]
    w = (tree.z - tree.centers[cfg.nlevels][lid]) / rho[lid]

    def seg(v):
        return jax.ops.segment_sum(v, lid, num_segments=nb,
                                   indices_are_sorted=True)

    if cfg.kernel == "harmonic":
        coeffs = [jnp.zeros(nb, tree.q.dtype)]
        pw = tree.q / rho[lid]
        for _ in range(cfg.p):
            coeffs.append(-seg(pw))
            pw = pw * w
    else:  # log: a~_0 = sum q; a~_j = -sum q w^j / j  (w already /rho)
        coeffs = [seg(tree.q)]
        pw = tree.q
        for j in range(1, cfg.p + 1):
            pw = pw * w
            coeffs.append(-seg(pw) / j)
    return jnp.stack(coeffs, axis=-1)


def m2m_level(child_coeffs: jax.Array, tree: Tree, l: int,
              cfg: FmmConfig, rho_child, rho_parent) -> jax.Array:
    """Shift level-(l+1) multipoles into level-l parents; sum 4 children."""
    nb_child = 4 ** (l + 1)
    parent = jnp.arange(nb_child, dtype=jnp.int32) // 4
    t = tree.centers[l + 1] - tree.centers[l][parent]
    u = t / rho_parent[parent]
    ratio = (rho_child / rho_parent[parent]).astype(child_coeffs.dtype)
    shifted = E.m2m_norm(child_coeffs, u, ratio)
    return shifted.reshape(4**l, 4, cfg.p + 1).sum(axis=1)


def upward(tree: Tree, cfg: FmmConfig, rho=None) -> list[jax.Array]:
    """Normalized multipole coefficients per level (l -> (4**l, p+1))."""
    if rho is None:
        rho = effective_radii(tree, cfg)
    m = [None] * (cfg.nlevels + 1)
    m[cfg.nlevels] = p2m(tree, cfg, rho[cfg.nlevels])
    for l in range(cfg.nlevels - 1, -1, -1):
        m[l] = m2m_level(m[l + 1], tree, l, cfg, rho[l + 1], rho[l])
    return m


# ---------------------------------------------------------------------------
# downward phase
# ---------------------------------------------------------------------------

def m2l_level(mult: jax.Array, weak: jax.Array, centers: jax.Array,
              cfg: FmmConfig, mat, rho) -> jax.Array:
    """Sum of M2L translations into each box of one level (normalized).

    Chunked over the padded weak list to bound the (B, chunk, p+1) working
    set — the jnp analogue of the paper's shared-memory staging; the Pallas
    kernel (kernels/m2l.py) performs the same computation with explicit
    VMEM tiles.
    """
    nb, W = weak.shape
    c = cfg.m2l_chunk
    pad = (-W) % c
    wk_all = jnp.pad(weak, ((0, 0), (0, pad)), constant_values=-1)
    chunks = wk_all.reshape(nb, -1, c).transpose(1, 0, 2)  # (n_chunks, nb, c)

    def body(acc, wk):
        mask = wk >= 0
        src = jnp.where(mask, wk, 0)
        a = jnp.where(mask[..., None], mult[src], 0.0)
        r = jnp.where(mask, centers[:, None] - centers[src], 1.0)
        rho_s = jnp.where(mask, rho[src], 0.0)
        rho_t = rho[:, None]
        if cfg.translations == "mxu":
            contrib = E.m2l_norm(a, r, rho_s, rho_t, mat)
        else:
            contrib = E.m2l_norm_horner(a, r, rho_s, rho_t)
        return acc + contrib.sum(axis=1), None

    out, _ = jax.lax.scan(body, jnp.zeros((nb, cfg.p + 1), mult.dtype),
                          chunks)
    return out


def l2l_level(parent_local: jax.Array, tree: Tree, l: int,
              cfg: FmmConfig, rho_child, rho_parent) -> jax.Array:
    """Shift level-(l-1) locals down to level-l children (normalized)."""
    nb = 4**l
    parent = jnp.arange(nb, dtype=jnp.int32) // 4
    s = tree.centers[l] - tree.centers[l - 1][parent]
    v = s / rho_parent[parent]
    ratio = (rho_child / rho_parent[parent]).astype(parent_local.dtype)
    return E.l2l_norm(parent_local[parent], v, ratio)


def p2l_sweep(local: jax.Array, tree: Tree, conn: Connectivity,
              cfg: FmmConfig, idx: jax.Array, rho) -> jax.Array:
    """Direct particle->local shifts for swapped-theta leaf pairs
    (radius-normalized: b~_l = sum q/(x-z0) * (rho_t/(x-z0))^l).

    Scanned over list slots (one compiled body regardless of the cap)."""
    z0 = tree.centers[cfg.nlevels]

    def body(acc, src):
        bmask = src >= 0
        srcc = jnp.where(bmask, src, 0)
        pidx = idx[srcc]                                  # (nb, n_max)
        pmask = (pidx >= 0) & bmask[:, None]
        safe = jnp.where(pidx >= 0, pidx, 0)
        pz = tree.z[safe]
        pq = jnp.where(pmask, tree.q[safe], 0.0)
        inv = jnp.where(pmask, 1.0 / (pz - z0[:, None]), 0.0)
        w = rho[:, None] * inv
        if cfg.kernel == "harmonic":
            pw = pq * inv
            updates = []
            for _ in range(cfg.p + 1):
                updates.append(pw.sum(axis=-1))
                pw = pw * w
        else:
            logs = jnp.where(pmask, jnp.log(z0[:, None] - pz), 0.0)
            updates = [(pq * logs).sum(axis=-1)]
            pw = pq * w
            for l in range(1, cfg.p + 1):
                updates.append(-(pw.sum(axis=-1)) / l)
                pw = pw * w
        return acc + jnp.stack(updates, axis=-1), None

    out, _ = jax.lax.scan(body, local, conn.p2l.T)
    return out


def _apply_p2l(local, tree, conn, cfg: FmmConfig, rho, p2l_impl):
    """Fold the leaf P2L contribution into ``local`` — via the reference
    jnp scan, or a ``p2l_impl(tree, conn, cfg, idx, rho_leaf)`` hook that
    returns the (nbox, p+1) contribution (the Pallas kernel)."""
    if not (cfg.use_p2l_m2p and cfg.nlevels > 0):
        return local
    idx = leaf_particle_index(cfg)
    if p2l_impl is None:
        return p2l_sweep(local, tree, conn, cfg, jnp.asarray(idx),
                         rho[cfg.nlevels])
    return local + p2l_impl(tree, conn, cfg, idx, rho[cfg.nlevels])


def downward(mult: list[jax.Array], tree: Tree, conn: Connectivity,
             cfg: FmmConfig, rho=None, p2l_impl=None) -> jax.Array:
    """Local coefficients at the leaf level (incl. M2L, L2L, P2L)."""
    p = cfg.p
    cdt = mult[-1].dtype
    m2l_mat = jnp.asarray(E.m2l_matrix(p), dtype=cfg.real_dtype)
    if rho is None:
        rho = effective_radii(tree, cfg)

    local = jnp.zeros((1, p + 1), dtype=cdt)
    for l in range(1, cfg.nlevels + 1):
        local = l2l_level(local, tree, l, cfg, rho[l], rho[l - 1])
        local = local + m2l_level(mult[l], conn.weak[l], tree.centers[l],
                                  cfg, m2l_mat, rho[l])
    if cfg.nlevels == 0:
        local = local + m2l_level(mult[0], conn.weak[0], tree.centers[0],
                                  cfg, m2l_mat, rho[0])
    return _apply_p2l(local, tree, conn, cfg, rho, p2l_impl)


# ---------------------------------------------------------------------------
# evaluation phase
# ---------------------------------------------------------------------------

def l2p(local: jax.Array, tree: Tree, cfg: FmmConfig, rho=None) -> jax.Array:
    """Evaluate leaf local expansions at the (sorted) particle positions."""
    lid = jnp.asarray(leaf_ids(cfg))
    if rho is None:
        rho = effective_radii(tree, cfg)[cfg.nlevels]
    t = (tree.z - tree.centers[cfg.nlevels][lid]) / rho[lid]
    b = local[lid]                                        # (N, p+1)
    acc = b[:, cfg.p]
    for j in range(cfg.p - 1, -1, -1):
        acc = acc * t + b[:, j]
    return acc


def m2p_sweep(phi: jax.Array, mult_leaf: jax.Array, tree: Tree,
              conn: Connectivity, cfg: FmmConfig, rho=None) -> jax.Array:
    """Evaluate source-box multipoles directly at target particles
    (normalized: Horner in w = rho_src/(z - z0_src))."""
    lid = jnp.asarray(leaf_ids(cfg))
    z0 = tree.centers[cfg.nlevels]
    if rho is None:
        rho = effective_radii(tree, cfg)[cfg.nlevels]

    def body(acc_phi, col):
        src = col[lid]                                    # (N,)
        mask = src >= 0
        srcc = jnp.where(mask, src, 0)
        a = mult_leaf[srcc]                               # (N, p+1)
        dz = tree.z - z0[srcc]
        w = jnp.where(mask, rho[srcc] / dz, 0.0)
        acc = a[:, cfg.p]
        for j in range(cfg.p - 1, 0, -1):
            acc = acc * w + a[:, j]
        acc = acc * w
        if cfg.kernel == "log":
            acc = acc + a[:, 0] * jnp.where(
                mask, jnp.log(jnp.where(mask, dz, 1.0)), 0.0)
        return acc_phi + jnp.where(mask, acc, 0.0), None

    out, _ = jax.lax.scan(body, phi, conn.m2p.T)
    return out


def p2p_sweep(phi: jax.Array, tree: Tree, conn: Connectivity,
              cfg: FmmConfig, idx: jax.Array) -> jax.Array:
    """Near-field direct evaluation over the leaf P2P lists (Alg. 3.7).

    Pure-jnp reference path; the Pallas kernel (kernels/p2p.py) implements
    the same contraction with VMEM source tiles.
    """
    nb, n_max = idx.shape
    tmask = idx >= 0
    tidx = jnp.where(tmask, idx, 0)
    tz = tree.z[tidx]                                     # (nb, n_max)

    def body(acc, src):
        bmask = src >= 0
        srcc = jnp.where(bmask, src, 0)
        sidx = idx[srcc]
        smask = (sidx >= 0) & bmask[:, None]
        siu = jnp.where(sidx >= 0, sidx, 0)
        sz = tree.z[siu]
        sq = jnp.where(smask, tree.q[siu], 0.0)
        diff = sz[:, None, :] - tz[:, :, None]            # (nb, n_t, n_s)
        # self-interaction excluded by particle identity (global rank),
        # not position: distinct coincident particles contribute their
        # (singular) mutual term — the sum_{j != i} semantics of eq. (1.1).
        ok = smask[:, None, :] & (sidx[:, None, :] != idx[:, :, None])
        if cfg.kernel == "harmonic":
            contrib = (jnp.where(ok, sq[:, None, :], 0.0)
                       / jnp.where(ok, diff, 1.0))
        else:
            contrib = jnp.where(ok, sq[:, None, :]
                                * jnp.log(jnp.where(ok, -diff, 1.0)), 0.0)
        return acc + contrib.sum(axis=-1), None

    acc, _ = jax.lax.scan(body, jnp.zeros_like(tz), conn.p2p.T)
    # scatter back to rank order (padded entries write a masked zero to rank 0)
    flat = jnp.where(tmask.reshape(-1), acc.reshape(-1), 0.0)
    return phi.at[tidx.reshape(-1)].add(flat)


# ---------------------------------------------------------------------------
# full pipeline
# ---------------------------------------------------------------------------

def fmm_build(z: jax.Array, q: jax.Array, cfg: FmmConfig,
              leaf_classify_impl=None) -> FmmPlan:
    """Topological phase: sort (single-sort tree build) + connect.

    ``leaf_classify_impl`` optionally replaces the leaf-level
    strong/weak/swapped-theta classification (the ``Backend.leaf_classify``
    topology hook — the Pallas kernel on the pallas backend)."""
    tree = build_tree(z, q, cfg)
    conn = build_connectivity(tree, cfg, leaf_classify_impl=leaf_classify_impl)
    return FmmPlan(tree=tree, conn=conn)


def fmm_evaluate(plan: FmmPlan, cfg: FmmConfig,
                 p2p_impl=None, m2l_impl=None, l2p_impl=None,
                 m2l_fused_impl=None, p2l_impl=None,
                 eval_fused_impl=None) -> jax.Array:
    """Run upward/downward/evaluation on a built plan; returns sorted phi.

    ``p2p_impl`` / ``m2l_impl`` / ``l2p_impl`` optionally override the
    near-field, M2L and L2P sweeps (used to swap in Pallas kernels; see
    ``repro.solver.backends`` for the registry that bundles them).
    ``m2l_fused_impl`` takes precedence over ``m2l_impl``: it receives the
    per-level sequences and computes the whole downward M2L in one launch
    (see ``downward_fused``). ``p2l_impl`` overrides the downward P2L
    scan (returns the (nbox, p+1) contribution). ``eval_fused_impl``
    takes precedence over the three evaluation hooks: it computes the
    whole evaluation phase (L2P + M2P + P2P) in one launch —
    ``eval_fused_impl(local, mult_leaf, tree, conn, cfg, idx) -> (n,)``.
    """
    tree, conn = plan.tree, plan.conn
    mult = upward(tree, cfg)

    if m2l_fused_impl is not None:
        local = downward_fused(mult, tree, conn, cfg, m2l_fused_impl,
                               p2l_impl)
    elif m2l_impl is None:
        local = downward(mult, tree, conn, cfg, p2l_impl=p2l_impl)
    else:
        local = downward_with(mult, tree, conn, cfg, m2l_impl, p2l_impl)

    # numpy constant (static layout): kernel wrappers derive shapes from it
    idx = leaf_particle_index(cfg)
    if eval_fused_impl is not None:
        return eval_fused_impl(local, mult[cfg.nlevels], tree, conn, cfg,
                               idx)

    if l2p_impl is None:
        phi = l2p(local, tree, cfg)
    else:
        phi = l2p_impl(local, tree, cfg, idx)
    if cfg.use_p2l_m2p:
        phi = m2p_sweep(phi, mult[cfg.nlevels], tree, conn, cfg)

    if p2p_impl is None:
        phi = p2p_sweep(phi, tree, conn, cfg, jnp.asarray(idx))
    else:
        phi = phi + p2p_impl(tree, conn, cfg, idx)
    return phi


def downward_with(mult, tree, conn, cfg, m2l_impl, p2l_impl=None) -> jax.Array:
    p = cfg.p
    rho = effective_radii(tree, cfg)
    local = jnp.zeros((1, p + 1), dtype=mult[-1].dtype)
    for l in range(1, cfg.nlevels + 1):
        local = l2l_level(local, tree, l, cfg, rho[l], rho[l - 1])
        local = local + m2l_impl(mult[l], conn.weak[l], tree.centers[l],
                                 cfg, rho[l])
    if cfg.nlevels == 0:
        local = local + m2l_impl(mult[0], conn.weak[0], tree.centers[0],
                                 cfg, rho[0])
    return _apply_p2l(local, tree, conn, cfg, rho, p2l_impl)


def downward_fused(mult, tree, conn, cfg, m2l_fused_impl,
                   p2l_impl=None) -> jax.Array:
    """Downward pass with the level-fused M2L hook (one launch, all levels).

    ``m2l_fused_impl(mult, weak, centers, cfg, rho)`` receives the
    per-level sequences and returns the per-level M2L contributions; the
    (cheap, inherently sequential) L2L recursion then folds them in
    level by level, replacing the per-level launch loop. ``p2l_impl``
    optionally replaces the leaf P2L scan (one more launch, no jnp
    fallback on the pallas path).
    """
    p = cfg.p
    rho = effective_radii(tree, cfg)
    contribs = m2l_fused_impl(mult, conn.weak, tree.centers, cfg, rho)
    local = jnp.zeros((1, p + 1), dtype=mult[-1].dtype)
    if cfg.nlevels == 0:
        local = local + contribs[0]
    else:
        for l in range(1, cfg.nlevels + 1):
            local = l2l_level(local, tree, l, cfg, rho[l], rho[l - 1])
            local = local + contribs[l - 1]
    return _apply_p2l(local, tree, conn, cfg, rho, p2l_impl)


@functools.partial(jax.jit, static_argnums=2)
def fmm_potential(z: jax.Array, q: jax.Array, cfg: FmmConfig) -> jax.Array:
    """Phi(z_i) = sum_{j != i} G(z_i, x_j) for all input points (eq. 1.1)."""
    plan = fmm_build(z, q, cfg)
    phi_sorted = fmm_evaluate(plan, cfg)
    out = jnp.zeros_like(phi_sorted)
    return out.at[plan.tree.perm].set(phi_sorted)


def fmm_potential_with_stats(z, q, cfg):
    """Non-jit variant returning (phi, connectivity stats)."""
    from .topology import connectivity_stats
    plan = fmm_build(z, q, cfg)
    phi_sorted = fmm_evaluate(plan, cfg)
    phi = jnp.zeros_like(phi_sorted).at[plan.tree.perm].set(phi_sorted)
    return phi, connectivity_stats(plan.conn)


def fmm_potential_checked(z, q, cfg: FmmConfig, max_grow: int = 3):
    """fmm_potential with interaction-list overflow validation.

    The padded-list caps are static shapes; if the input distribution
    overflows them the jit path would silently drop interactions. This
    wrapper checks the overflow scalar (one cheap eager build) and regrows
    the caps (x2, up to ``max_grow`` times) before evaluating. Production
    deployments pin the grown config and stay on the jit path.
    """
    import dataclasses

    for _ in range(max_grow + 1):
        plan = fmm_build(z, q, cfg)
        if int(jax.device_get(plan.conn.overflow)) == 0:
            phi_sorted = fmm_evaluate(plan, cfg)
            out = jnp.zeros_like(phi_sorted)
            return out.at[plan.tree.perm].set(phi_sorted), cfg
        cfg = dataclasses.replace(cfg, strong_cap=2 * cfg.strong_cap,
                                  weak_cap=0)
    from ..errors import CapOverflowError
    raise CapOverflowError(
        f"interaction lists overflow even at strong_cap={cfg.strong_cap}")

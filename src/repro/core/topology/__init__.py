"""Device-resident topology subsystem (paper §4.1–§4.3).

The paper's headline claim is that *every* phase runs on the GPU,
"including the initial phase which assembles the topological information
of the input data". This package is that phase for the TPU port:

  tree.py          single-sort adaptive tree build (2 full sorts total,
                   then O(N) segmented rank-partitions per split) plus
                   the fused level-geometry pass
  connectivity.py  theta-criterion interaction lists with the per-level
                   compaction batched into one flattened sort and the
                   leaf-level classification exposed as a backend hook
                   (jnp reference | Pallas kernel)

``repro.core`` re-exports the public names, so callers keep importing
``from repro.core import build_tree, build_connectivity``.
"""
from .tree import (Tree, build_tree, build_tree_lexsort, leaf_ids,
                   leaf_particle_index, leaf_particle_index_loop)
from .connectivity import (MARGIN_CLASSES, Connectivity, build_connectivity,
                           connectivity_stats, leaf_classify_reference)

__all__ = [
    "Tree", "build_tree", "build_tree_lexsort", "leaf_ids",
    "leaf_particle_index", "leaf_particle_index_loop",
    "Connectivity", "MARGIN_CLASSES", "build_connectivity",
    "connectivity_stats", "leaf_classify_reference",
]

"""Theta-criterion connectivity (paper §2, eq. (2.1)) — batched build.

Per level l, every box carries a *directed* strong list and a *directed*
weak (M2L) list, padded to static caps — the paper's §4.3 design: the GPU
(here: TPU) version deliberately duplicates symmetric pairs so each box's
interactions can be computed independently without atomics; the paper
measures the cost of this at ~1% of runtime.

Candidates for box b at level l are exactly the children of the strong set
of b's parent (paper §2); each candidate is classified by

    well-separated(b, c)  <=>  R + theta*r <= theta*d,
    R = max(r_b, r_c), r = min(r_b, r_c), d = |z_b - z_c|.

At the leaf level, strong pairs are re-tested with r/R roles swapped
(Carrier-Greengard optimization, paper §2): passing pairs become P2L (the
larger box's particles shift directly into the smaller box's local
expansion) / M2P (the smaller box's multipole is evaluated directly at the
larger box's points) instead of P2P.

Batched layout (the level-fused M2L's static-offset trick, applied to the
topology phase): the strong-set recursion is inherently sequential in l
(level-l candidates are children of the level-(l-1) strong set), but
everything *after* classification is not. All candidate widths are the
same static ``4*strong_cap``, so every level's weak list plus the five
leaf classes stack into ONE flattened ``(sum 4**l, 4S)`` array that is
compacted by a single batched sort — one launch where the seed did
``2L + 3`` per-level compactions. The leaf level (3/4 of all boxes)
classifies through a backend hook (``leaf_classify_impl``): the jnp
reference below, or the Pallas kernel in ``repro.kernels.topology``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..config import FmmConfig
from .tree import Tree

_INT_MAX = jnp.iinfo(jnp.int32).max


#: Order of the per-class cap-margin vector (``Connectivity.margins``).
MARGIN_CLASSES = ("strong", "weak", "p2p", "p2l", "m2p")


class Connectivity(NamedTuple):
    strong: tuple[jax.Array, ...]   # level l: (4**l, strong_cap) int32, -1 pad
    weak: tuple[jax.Array, ...]     # level l: (4**l, weak_cap)
    p2p: jax.Array                  # leaf: (4**L, strong_cap)
    p2l: jax.Array                  # leaf: (4**L, strong_cap)
    m2p: jax.Array                  # leaf: (4**L, strong_cap)
    overflow: jax.Array             # scalar int32; 0 iff no list overflowed
    margins: jax.Array              # (5,) int32 per-class cap margins in
    #                                 MARGIN_CLASSES order: slots left on the
    #                                 fullest row (min over levels); negative
    #                                 = that many entries were dropped. The
    #                                 in-graph health plane reads this —
    #                                 overflow == max(0, -margins.min()).


def _keyed(vals: jax.Array, mask: jax.Array) -> jax.Array:
    """Sort keys for row compaction: kept entries ascend, dropped sink."""
    return jnp.where(mask, vals, _INT_MAX)


def _compact(vals: jax.Array, mask: jax.Array, cap: int):
    """Row-compact masked entries to the front, pad with -1, clip to cap.

    Returns (compacted (B, cap), margin ()) where margin is the cap
    margin of the fullest row — ``cap - max(count)``, negative when that
    many entries were dropped.
    """
    srt = jnp.sort(_keyed(vals, mask), axis=-1)
    count = mask.sum(axis=-1)
    kept = srt[..., :cap]
    out = jnp.where(kept == _INT_MAX, -1, kept)
    margin = (cap - count.max()).astype(jnp.int32)
    return out, margin


def _theta_masks(cbx, cby, rb, ccx, ccy, rc, valid, theta):
    """(weak_mask, strong_mask) on real coordinate planes.

    Plane form (rather than complex ``abs``) so the jnp reference and the
    Pallas classification kernel evaluate the *same* elementwise formula
    — the two paths must agree on every boundary case bit-for-bit.
    """
    d = jnp.hypot(cbx[:, None] - ccx, cby[:, None] - ccy)
    big = jnp.maximum(rb[:, None], rc)
    small = jnp.minimum(rb[:, None], rc)
    wellsep = (big + theta * small) <= (theta * d)
    return valid & wellsep, valid & ~wellsep


def _gather_geometry(cand, valid, centers, radii):
    """(ccx, ccy, rc) of the candidate boxes, zeroed where invalid."""
    idx = jnp.where(valid, cand, 0)
    ccx = jnp.where(valid, jnp.real(centers)[idx], 0.0)
    ccy = jnp.where(valid, jnp.imag(centers)[idx], 0.0)
    rc = jnp.where(valid, radii[idx], 0.0)
    return ccx, ccy, rc


def _swapped_masks(cbx, cby, rb, ccx, ccy, rc, strong_mask, cfg: FmmConfig):
    """Leaf reclassification: (p2p, p2l, m2p) masks over the strong set."""
    if not cfg.use_p2l_m2p:
        zero = jnp.zeros_like(strong_mask)
        return strong_mask, zero, zero
    d = jnp.hypot(cbx[:, None] - ccx, cby[:, None] - ccy)
    big = jnp.maximum(rb[:, None], rc)
    small = jnp.minimum(rb[:, None], rc)
    swapped = (small + cfg.theta * big) <= (cfg.theta * d)  # roles swapped
    p2l = strong_mask & swapped & (rc > rb[:, None])        # source larger
    m2p = strong_mask & swapped & (rc < rb[:, None])        # source smaller
    p2p = strong_mask & ~(p2l | m2p)
    return p2p, p2l, m2p


def leaf_classify_reference(cand, valid, centers, radii, cfg: FmmConfig):
    """Reference leaf-level classification (the ``leaf_classify_impl``
    hook's jnp twin — see ``repro.kernels.topology`` for the Pallas one).

    ``cand``/``valid``: (4**L, 4S) candidate boxes (children of the
    parent's strong set). Returns five (4**L, 4S) int32 *keyed* arrays
    (strong, weak, p2p, p2l, m2p): kept entries carry the candidate id,
    dropped entries ``INT32_MAX`` — ready for the caller's batched
    compaction sort.
    """
    cbx, cby = jnp.real(centers), jnp.imag(centers)
    rb = radii
    ccx, ccy, rc = _gather_geometry(cand, valid, centers, radii)
    weak_m, strong_m = _theta_masks(cbx, cby, rb, ccx, ccy, rc, valid,
                                    cfg.theta)
    p2p_m, p2l_m, m2p_m = _swapped_masks(cbx, cby, rb, ccx, ccy, rc,
                                         strong_m, cfg)
    return (_keyed(cand, strong_m), _keyed(cand, weak_m),
            _keyed(cand, p2p_m), _keyed(cand, p2l_m), _keyed(cand, m2p_m))


def _batched_compact(groups):
    """ONE sort for every (keys, cap) group: stack the same-width keyed
    arrays, sort once along the slot axis, then slice each group at its
    own cap. Returns (lists, margins) aligned with ``groups``; each
    margin is ``cap - max(count)`` over the group's rows (negative =
    entries dropped)."""
    keys = jnp.concatenate([k for k, _ in groups], axis=0)
    srt = jnp.sort(keys, axis=-1)
    counts = (keys != _INT_MAX).sum(axis=-1)
    lists, margins = [], []
    row = 0
    for k, cap in groups:
        nb = k.shape[0]
        kept = srt[row:row + nb, :cap]
        lists.append(jnp.where(kept == _INT_MAX, -1, kept))
        margins.append((cap - counts[row:row + nb].max()).astype(jnp.int32))
        row += nb
    return lists, margins


def _overflow_of(margins) -> jax.Array:
    """Dropped-entry count implied by a set of margins (0 iff all >= 0)."""
    worst = jnp.minimum(jnp.stack([jnp.asarray(m) for m in margins]).min(),
                        0)
    return (-worst).astype(jnp.int32)


def build_connectivity(tree: Tree, cfg: FmmConfig,
                       leaf_classify_impl=None) -> Connectivity:
    """Interaction lists for every level, ready for the static sweeps.

    ``leaf_classify_impl(cand, valid, centers, radii, cfg)`` optionally
    replaces the leaf-level strong/weak/swapped-theta classification
    (the Pallas topology kernel); ``None`` runs the jnp reference. The
    recursion over levels is irreducible (candidates are children of the
    parent's strong set) but runs on (4**l, 4S) arrays with no host
    round-trip, and all compactions below the strong recursion are
    batched into one flattened sort.
    """
    theta = cfg.theta
    S, W = cfg.strong_cap, cfg.weak_cap
    L = cfg.nlevels
    classify = (leaf_classify_impl if leaf_classify_impl is not None
                else leaf_classify_reference)

    strong = [jnp.zeros((1, S), jnp.int32).at[:, 1:].set(-1)]  # root: self
    weak = [jnp.full((1, W), -1, jnp.int32)]
    # per-class cap margins (MARGIN_CLASSES order); root lists are
    # structural: strong = self (1 entry), weak = empty
    root_strong_margin = jnp.asarray(S - 1, jnp.int32)
    root_weak_margin = jnp.asarray(W, jnp.int32)

    if L == 0:
        # Degenerate 1-box problem: the root strong list is *defined* as
        # self (never theta-tested), so only the swapped-theta
        # reclassification applies. Hook not engaged (nothing to batch).
        st = strong[0]
        valid = st >= 0
        cbx, cby = jnp.real(tree.centers[0]), jnp.imag(tree.centers[0])
        ccx, ccy, rc = _gather_geometry(st, valid, tree.centers[0],
                                        tree.radii[0])
        p2p_m, p2l_m, m2p_m = _swapped_masks(cbx, cby, tree.radii[0], ccx,
                                             ccy, rc, valid, cfg)
        (p2p, p2l, m2p), class_margins = _batched_compact(
            [(_keyed(st, p2p_m), S), (_keyed(st, p2l_m), S),
             (_keyed(st, m2p_m), S)])
        margins = jnp.stack([root_strong_margin, root_weak_margin]
                            + class_margins)
        return Connectivity(strong=tuple(strong), weak=tuple(weak),
                            p2p=p2p, p2l=p2l, m2p=m2p,
                            overflow=_overflow_of([margins]),
                            margins=margins)

    weak_keys = []
    strong_margins = [root_strong_margin]
    leaf_keys = None
    for l in range(1, L + 1):
        nb = 4**l
        box = jnp.arange(nb, dtype=jnp.int32)
        parent_strong = strong[l - 1][box // 4]                 # (nb, S)
        pvalid = parent_strong >= 0
        cand = (jnp.where(pvalid, parent_strong, 0)[:, :, None] * 4
                + jnp.arange(4, dtype=jnp.int32)).reshape(nb, 4 * S)
        valid = jnp.repeat(pvalid, 4, axis=-1)

        if l == L:
            leaf_keys = classify(cand, valid, tree.centers[l],
                                 tree.radii[l], cfg)
            weak_keys.append(leaf_keys[1])
            continue

        cbx, cby = jnp.real(tree.centers[l]), jnp.imag(tree.centers[l])
        ccx, ccy, rc = _gather_geometry(cand, valid, tree.centers[l],
                                        tree.radii[l])
        weak_mask, strong_mask = _theta_masks(cbx, cby, tree.radii[l], ccx,
                                              ccy, rc, valid, theta)
        weak_keys.append(_keyed(cand, weak_mask))
        # the recursion consumes strong[l] next iteration: compact in-loop
        s_l, s_mg = _compact(cand, strong_mask, S)
        strong.append(s_l)
        strong_margins.append(s_mg)

    # ---- batched compaction: one sort over the flattened (sum 4**l, 4S)
    # stack — every level's weak list + the leaf's five classes ---------
    strong_key, _, p2p_key, p2l_key, m2p_key = leaf_keys
    groups = ([(k, W) for k in weak_keys]
              + [(strong_key, S), (p2p_key, S), (p2l_key, S), (m2p_key, S)])
    lists, group_margins = _batched_compact(groups)
    weak_lists, (strong_L, p2p, p2l, m2p) = lists[:L], lists[L:]
    weak_margins, strong_margins_tail = group_margins[:L], group_margins[L:]
    strong.append(strong_L)
    weak.extend(weak_lists)

    margins = jnp.stack([
        jnp.stack(strong_margins + [strong_margins_tail[0]]).min(),
        jnp.stack([root_weak_margin] + weak_margins).min(),
        strong_margins_tail[1],     # p2p
        strong_margins_tail[2],     # p2l
        strong_margins_tail[3],     # m2p
    ])
    return Connectivity(strong=tuple(strong), weak=tuple(weak),
                        p2p=p2p, p2l=p2l, m2p=m2p,
                        overflow=_overflow_of([margins]), margins=margins)


def connectivity_stats(conn: Connectivity) -> dict:
    """Interaction counts per phase (for the paper's Table 5.1 analysis).

    ONE ``jax.device_get`` moves the whole Connectivity pytree to host
    (a no-op on already-fetched numpy inputs); the per-level/per-list
    reductions then run in numpy, so a stats call costs a single
    device sync instead of one per level per counter.
    """
    import numpy as np

    conn = jax.device_get(conn)
    strong = [np.asarray(s) for s in conn.strong]
    weak = [np.asarray(w) for w in conn.weak]
    margins = np.asarray(conn.margins)
    return {
        "m2l_pairs": int(sum(int((w >= 0).sum()) for w in weak)),
        "p2p_pairs": int((np.asarray(conn.p2p) >= 0).sum()),
        "p2l_pairs": int((np.asarray(conn.p2l) >= 0).sum()),
        "m2p_pairs": int((np.asarray(conn.m2p) >= 0).sum()),
        "strong_max": max(int((s >= 0).sum(-1).max()) for s in strong),
        "weak_max": max(int((w >= 0).sum(-1).max()) for w in weak),
        "overflow": int(np.asarray(conn.overflow)),
        "margins": {c: int(m) for c, m in zip(MARGIN_CLASSES, margins)},
    }

"""Asymmetric adaptive FMM tree (paper §2, [7]) — single-sort build.

Boxes are split at the particle *median*, twice per level, along the most
eccentric axis -> a perfectly balanced 4-ary pyramid. Because splits happen
at exact ranks, box b at level l owns the contiguous rank-slice
``[bounds[l][b], bounds[l][b+1])`` where the bounds depend only on (N, l):
a *static memory layout*, which is the property the whole GPU (here: TPU)
implementation is organized around.

Single-sort scheme (DESIGN.md §8): the seed implementation re-sorted the
full particle array once per split — ``2*nlevels`` O(N log N) lexsorts.
This build sorts exactly **twice** (one ``argsort`` per coordinate) and
then maintains, through every split, two id arrays ``A_x``/``A_y`` that
are segment-contiguous at the static rank bounds and internally sorted by
x resp. y. Each median split is then O(N) sort-free work:

  * segment extents are *gathers of boundary elements* of A_x/A_y (the
    min/max of a sorted run are its endpoints), giving the eccentric-axis
    choice without a segmented reduction;
  * "goes left" is a static positional predicate in the chosen axis's
    array (the first ceil(n/2) entries of the segment), scattered to
    particle ids;
  * both arrays are *stable-partitioned* at the static median ranks with
    one cumulative sum — the classic presorted kd-tree construction,
    mapped to scatters so every step is an O(N) data-parallel primitive.

The final rank order equals the lexsort cascade's for inputs with
distinct coordinates (ties break by initial argsort order instead of the
evolving order — a measure-zero difference on continuous inputs); the
parity sweep in tests/test_topology.py checks bit-identical rank layout
against ``build_tree_lexsort``, the seed implementation kept as oracle.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from ..config import FmmConfig, level_bounds, segment_ids, split_bounds


class Tree(NamedTuple):
    """Sorted particles + per-level box geometry. All shapes static."""

    perm: jax.Array          # (N,) int32; sorted_field[i] corresponds to input index perm[i]
    z: jax.Array             # (N,) complex, rank-sorted positions
    q: jax.Array             # (N,) complex, rank-sorted strengths
    centers: tuple[jax.Array, ...]   # level l: (4**l,) complex
    radii: tuple[jax.Array, ...]     # level l: (4**l,) real


def _seg_minmax(v: jax.Array, sid: jax.Array, nseg: int):
    mn = jax.ops.segment_min(v, sid, num_segments=nseg, indices_are_sorted=True)
    mx = jax.ops.segment_max(v, sid, num_segments=nseg, indices_are_sorted=True)
    return mn, mx


def _partition(order, left_of, starts_pos, mids_pos, offs_pos):
    """Stable-partition ``order`` within static segments by a per-id flag.

    ``order``: (N,) int32 particle ids, segment-contiguous at the static
    bounds and internally sorted by one coordinate. ``left_of``: (N,)
    bool per particle *id*. ``starts_pos``/``mids_pos``/``offs_pos``:
    (N,) static per-position segment start / median rank / offset within
    the segment. Left entries keep their relative order in
    ``[start, mid)``, right entries in ``[mid, end)`` — so both coordinate
    orders survive every split without re-sorting.
    """
    f = left_of[order]
    lefts = jnp.cumsum(f.astype(jnp.int32)) - f    # exclusive: lefts in [0, p)
    seg_l = lefts - lefts[starts_pos]              # lefts before p in segment
    seg_r = offs_pos - seg_l                       # rights before p in segment
    dest = jnp.where(f, starts_pos + seg_l, mids_pos + seg_r)
    return jnp.zeros_like(order).at[dest].set(order)


def build_tree(z: jax.Array, q: jax.Array, cfg: FmmConfig) -> Tree:
    """Sort particles into the static pyramid layout and compute geometry.

    Exactly two full-array sorts (one argsort per coordinate) regardless
    of depth; everything else is cumsum/gather/scatter. The jaxpr
    sort-count test in tests/test_topology.py pins this property.
    """
    rdt = cfg.real_dtype
    cdt = cfg.complex_dtype
    z = z.astype(cdt)
    q = q.astype(cdt)
    x = jnp.real(z).astype(rdt)
    y = jnp.imag(z).astype(rdt)
    N, L = cfg.n, cfg.nlevels

    if L == 0:
        perm = jnp.arange(N, dtype=jnp.int32)
    else:
        ax = jnp.argsort(x).astype(jnp.int32)      # full sort 1 (stable)
        ay = jnp.argsort(y).astype(jnp.int32)      # full sort 2 (stable)
        sb = split_bounds(N, 2 * L)
        split_x = None
        for s in range(2 * L):
            b = sb[s]
            mids = sb[s + 1][1::2]
            sid_pos = segment_ids(b)                       # static (N,)
            starts_pos = jnp.asarray(b[:-1][sid_pos])
            mids_pos = jnp.asarray(mids[sid_pos])
            offs_pos = jnp.asarray(np.arange(N) - b[:-1][sid_pos])
            # sorted-run endpoints ARE the segment extents: 2 gathers/axis
            jst, jla = jnp.asarray(b[:-1]), jnp.asarray(b[1:] - 1)
            xmn, xmx = x[ax[jst]], x[ax[jla]]
            ymn, ymx = y[ay[jst]], y[ay[jla]]
            split_x = (xmx - xmn) >= (ymx - ymn)           # (2**s,)
            # positional "first half of my segment" flag, static per rank
            pos_left = jnp.asarray(np.arange(N) < mids[sid_pos])
            xleft = jnp.zeros(N, bool).at[ax].set(pos_left)
            yleft = jnp.zeros(N, bool).at[ay].set(pos_left)
            sid_of_id = jnp.zeros(N, jnp.int32).at[ax].set(
                jnp.asarray(sid_pos))
            goes_left = jnp.where(split_x[sid_of_id], xleft, yleft)
            ax = _partition(ax, goes_left, starts_pos, mids_pos, offs_pos)
            ay = _partition(ay, goes_left, starts_pos, mids_pos, offs_pos)
        # Final rank order within each leaf = ascending in the axis its
        # parent split on (what the lexsort cascade leaves behind): both
        # id arrays are leaf-contiguous at the same static bounds, so the
        # choice is a positionwise select.
        leaf_pos = segment_ids(sb[2 * L])                  # static (N,)
        choose_x = split_x[jnp.asarray(leaf_pos // 2)]
        perm = jnp.where(choose_x, ax, ay)

    xs, ys = x[perm], y[perm]
    z_sorted = (xs + 1j * ys).astype(cdt)
    q_sorted = q[perm]
    centers, radii = _level_geometry(xs, ys, cfg)
    return Tree(perm=perm, z=z_sorted, q=q_sorted,
                centers=centers, radii=radii)


def _level_geometry(xs, ys, cfg: FmmConfig):
    """Shrink-to-fit centers/radii for every level from ONE segmented pass.

    The four segmented min/max reductions run once, over the leaf boxes;
    every coarser level's extents are 4-child min/max reductions of the
    (4**l,) level arrays (exact: min over a box == min of its children's
    mins), so the O(N) geometry work is not repeated per level.
    """
    rdt, cdt = cfg.real_dtype, cfg.complex_dtype
    lid = jnp.asarray(leaf_ids(cfg))
    nb = 4 ** cfg.nlevels
    xmn, xmx = _seg_minmax(xs, lid, nb)
    ymn, ymx = _seg_minmax(ys, lid, nb)
    centers: list = [None] * (cfg.nlevels + 1)
    radii: list = [None] * (cfg.nlevels + 1)
    for l in range(cfg.nlevels, -1, -1):
        cx = 0.5 * (xmn + xmx)
        cy = 0.5 * (ymn + ymx)
        centers[l] = (cx + 1j * cy).astype(cdt)
        radii[l] = (0.5 * jnp.hypot(xmx - xmn, ymx - ymn)).astype(rdt)
        if l > 0:
            xmn = xmn.reshape(-1, 4).min(axis=1)
            xmx = xmx.reshape(-1, 4).max(axis=1)
            ymn = ymn.reshape(-1, 4).min(axis=1)
            ymx = ymx.reshape(-1, 4).max(axis=1)
    return tuple(centers), tuple(radii)


def build_tree_lexsort(z: jax.Array, q: jax.Array, cfg: FmmConfig) -> Tree:
    """Seed implementation (one full lexsort per split), kept as the
    parity oracle for ``build_tree`` — see tests/test_topology.py."""
    rdt = cfg.real_dtype
    cdt = cfg.complex_dtype
    z = z.astype(cdt)
    q = q.astype(cdt)
    x = jnp.real(z).astype(rdt)
    y = jnp.imag(z).astype(rdt)
    perm = jnp.arange(cfg.n, dtype=jnp.int32)

    sb = split_bounds(cfg.n, 2 * cfg.nlevels)
    for s in range(2 * cfg.nlevels):
        nseg = 2**s
        sid = jnp.asarray(segment_ids(sb[s]))
        xmn, xmx = _seg_minmax(x, sid, nseg)
        ymn, ymx = _seg_minmax(y, sid, nseg)
        split_x = (xmx - xmn) >= (ymx - ymn)
        coord = jnp.where(split_x[sid], x, y)
        order = jnp.lexsort((coord, sid))
        x, y, perm = x[order], y[order], perm[order]

    z_sorted = (x + 1j * y).astype(cdt)
    q_sorted = q[perm]

    centers = []
    radii = []
    lb = level_bounds(cfg)
    for l in range(cfg.nlevels + 1):
        nseg = 4**l
        sid = jnp.asarray(segment_ids(lb[l]))
        xmn, xmx = _seg_minmax(x, sid, nseg)
        ymn, ymx = _seg_minmax(y, sid, nseg)
        cx = 0.5 * (xmn + xmx)
        cy = 0.5 * (ymn + ymx)
        centers.append((cx + 1j * cy).astype(cdt))
        radii.append((0.5 * jnp.hypot(xmx - xmn, ymx - ymn)).astype(rdt))

    return Tree(perm=perm, z=z_sorted, q=q_sorted,
                centers=tuple(centers), radii=tuple(radii))


def leaf_particle_index(cfg: FmmConfig) -> np.ndarray:
    """(4**L, n_max) int32 gather map leaf-box -> particle ranks, -1 padded.

    Purely static (depends only on N and nlevels) — this is the paper's
    "static layout of memory" made literal: the map is a numpy constant
    baked into the compiled program. Built by broadcasting the leaf rank
    bounds against a column index (no per-box Python loop).
    """
    lb = level_bounds(cfg)[-1]
    sizes = np.diff(lb)
    n_max = int(sizes.max())
    col = np.arange(n_max, dtype=np.int64)
    idx = lb[:-1, None] + col[None, :]
    return np.where(col[None, :] < sizes[:, None], idx, -1).astype(np.int32)


def leaf_particle_index_loop(cfg: FmmConfig) -> np.ndarray:
    """Seed O(4**L) Python-loop construction, kept as parity oracle."""
    lb = level_bounds(cfg)[-1]
    sizes = np.diff(lb)
    n_max = int(sizes.max())
    nbox = len(sizes)
    idx = np.full((nbox, n_max), -1, dtype=np.int32)
    for b in range(nbox):
        idx[b, : sizes[b]] = np.arange(lb[b], lb[b + 1], dtype=np.int32)
    return idx


def leaf_ids(cfg: FmmConfig) -> np.ndarray:
    """(N,) int32: leaf box owning each rank."""
    return segment_ids(level_bounds(cfg)[-1])

from .optim import OptConfig, init_opt_state, apply_updates, lr_schedule
from .optim import global_norm

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "lr_schedule",
           "global_norm"]

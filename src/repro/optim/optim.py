"""Optimizers: AdamW (configurable state dtype) and Adafactor (factored
second moment — the memory-feasible choice for the 300B+ archs), plus
global-norm clipping and warmup-cosine schedule. Pure pytree functions; no
external deps. Weight decay masks out 1-D params (norm gains, biases).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 200
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"     # "bfloat16" halves optimizer memory
    # adafactor
    factored_min_dim: int = 128
    decay_rate: float = 0.8


def lr_schedule(step, oc: OptConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(oc.warmup, 1))
    t = jnp.clip((step - oc.warmup) / max(oc.total_steps - oc.warmup, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return oc.lr * warm * (oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decay_mask(p):
    return jnp.asarray(1.0 if p.ndim >= 2 else 0.0, jnp.float32)


def _factored(shape, min_dim):
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


# ---------------------------------------------------------------------------

def init_opt_state(params, oc: OptConfig) -> dict[str, Any]:
    sdt = jnp.dtype(oc.state_dtype)
    if oc.name == "adamw":
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
        }
    if oc.name == "adafactor":
        def vrow(p):
            if _factored(p.shape, oc.factored_min_dim):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vcol(p):
            if _factored(p.shape, oc.factored_min_dim):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,) * p.ndim, jnp.float32)

        return {
            "vr": jax.tree.map(vrow, params),
            "vc": jax.tree.map(vcol, params),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                              params),
        }
    raise ValueError(oc.name)


def _clip(grads, oc: OptConfig):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def apply_updates(params, grads, state, step, oc: OptConfig):
    """Returns (new_params, new_state, stats)."""
    grads, gn = _clip(grads, oc)
    lr = lr_schedule(step, oc)
    stats = {"grad_norm": gn, "lr": lr}
    t = (step + 1).astype(jnp.float32)

    if oc.name == "adamw":
        bc1 = 1 - oc.b1 ** t
        bc2 = 1 - oc.b2 ** t

        def upd(p, g, m, v):
            m32 = m.astype(jnp.float32) * oc.b1 + g * (1 - oc.b1)
            v32 = v.astype(jnp.float32) * oc.b2 + g * g * (1 - oc.b2)
            u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + oc.eps)
            u = u + oc.weight_decay * _decay_mask(p) * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * u
            return (newp.astype(p.dtype), m32.astype(m.dtype),
                    v32.astype(v.dtype))

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        newp = treedef.unflatten([l[0] for l in leaves])
        newm = treedef.unflatten([l[1] for l in leaves])
        newv = treedef.unflatten([l[2] for l in leaves])
        return newp, {"m": newm, "v": newv}, stats

    # ---- adafactor ---------------------------------------------------------
    beta2 = 1.0 - t ** (-oc.decay_rate)

    def upd(p, g, vr, vc, m):
        g2 = g * g + 1e-30
        if _factored(p.shape, oc.factored_min_dim):
            vr32 = vr * beta2 + g2.mean(axis=-1) * (1 - beta2)
            vc32 = vc * beta2 + g2.mean(axis=-2) * (1 - beta2)
            denom = (vr32 / jnp.maximum(
                vr32.mean(axis=-1, keepdims=True), 1e-30))[..., None] \
                * vc32[..., None, :]
            u = g * jax.lax.rsqrt(denom + 1e-30)
        else:
            vr32 = vr * beta2 + g2 * (1 - beta2)
            vc32 = vc
            u = g * jax.lax.rsqrt(vr32 + 1e-30)
        # update clipping (Shazeer-Stern): rms(u) <= 1
        urms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, urms)
        m32 = m.astype(jnp.float32) * oc.b1 + u * (1 - oc.b1)
        u = m32
        u = u + oc.weight_decay * _decay_mask(p) * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * u
        return (newp.astype(p.dtype), vr32, vc32, m32.astype(m.dtype))

    out = jax.tree.map(upd, params, grads, state["vr"], state["vc"],
                       state["m"])
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    newp = treedef.unflatten([l[0] for l in leaves])
    newvr = treedef.unflatten([l[1] for l in leaves])
    newvc = treedef.unflatten([l[2] for l in leaves])
    newm = treedef.unflatten([l[3] for l in leaves])
    return newp, {"vr": newvr, "vc": newvc, "m": newm}, stats

"""Typed failure taxonomy for the FMM pipeline (DESIGN.md §9).

Every loud failure path in the solver raises one of these instead of a
bare ``RuntimeError``/``ValueError``, so callers (and the guarded
execution ladder, ``repro.solver.guard``) can branch on *what* failed:

  ValidationError      caller handed us malformed arguments (shape,
                       dtype, batch layout) — never recoverable by the
                       ladder, always the caller's bug
  CapOverflowError     the connectivity caps dropped interactions — the
                       answer would be silently wrong; recoverable by
                       cap escalation (or ``core.direct`` as the floor)
  NonFiniteInputError  z or q contain NaN/Inf — garbage in; nothing
                       downstream can recover, fail before compute
  NonFiniteOutputError phi contains NaN/Inf on finite input — a kernel
                       or expansion bug; recoverable by degrading the
                       offending phase to the reference backend
  RecoveryExhaustedError  every rung of the recovery ladder failed

The classes multiply-inherit the builtin the pre-taxonomy code raised
(``ValueError`` for validation, ``RuntimeError`` for overflow), so
``except RuntimeError`` call sites written against the old contract keep
working.
"""
from __future__ import annotations


class FmmError(Exception):
    """Base class of every typed FMM failure."""


class ValidationError(FmmError, ValueError):
    """Malformed solver arguments (shape / dtype / batch layout)."""


class ShapeError(ValidationError):
    """Argument shape does not match the solver's static config."""


class DTypeError(ValidationError, TypeError):
    """Argument dtype confusion (real positions, precision loss, ...)."""


class CapOverflowError(FmmError, RuntimeError):
    """Connectivity caps overflowed: interactions would be dropped.

    Carries ``margins`` — the per-class cap margins (slots left before
    overflow; negative = entries dropped) keyed by
    ``repro.core.fmm.HEALTH_CLASSES`` — and the scalar ``overflow``.
    """

    def __init__(self, message: str, *, margins: dict | None = None,
                 overflow: int = 0):
        super().__init__(message)
        self.margins = dict(margins or {})
        self.overflow = int(overflow)


class NonFiniteInputError(FmmError, ValueError):
    """z or q contain NaN/Inf — refusing to compute on garbage."""


class NonFiniteOutputError(FmmError, ArithmeticError):
    """phi contains NaN/Inf on finite input (kernel/expansion fault)."""


class RecoveryExhaustedError(FmmError, RuntimeError):
    """Every rung of the guarded-execution ladder failed.

    Carries ``report`` — the ``GuardReport`` of the failed walk."""

    def __init__(self, message: str, *, report=None):
        super().__init__(message)
        self.report = report


class DeadlineExceededError(FmmError, TimeoutError):
    """A served request's deadline budget ran out before it could be
    dispatched (admission control, ``repro.serve``). The request was
    shed, not computed — retrying with a fresh budget is the caller's
    call."""


class OversizedRequestError(ValidationError):
    """A served request's N exceeds the bucket lattice *and* the direct
    O(N^2) fallback bound — no shape class can absorb it. Raised (or
    recorded as the typed rejection in a ``ServeReport``) by the
    serving plane's admission controller."""


class BackendDowngradeWarning(RuntimeWarning):
    """A solver entry point silently dispatches a different backend than
    requested (e.g. ``apply_batched`` on a ``batched_dispatch="fallback"``
    backend). CI promotes this to an error in the tier-1 matrix — silent
    degradation fails the build."""

from .fused import eval_fused_pallas, eval_fused_pallas_batched
from .p2l import p2l_pallas, p2l_pallas_batched
from .ops import eval_fused_apply, p2l_apply
from .ref import m2p_ref

__all__ = ["eval_fused_pallas", "eval_fused_pallas_batched", "p2l_pallas",
           "p2l_pallas_batched", "eval_fused_apply", "p2l_apply", "m2p_ref"]

from .fused import eval_fused_pallas
from .p2l import p2l_pallas
from .ops import eval_fused_apply, p2l_apply
from .ref import m2p_ref

__all__ = ["eval_fused_pallas", "p2l_pallas", "eval_fused_apply",
           "p2l_apply", "m2p_ref"]

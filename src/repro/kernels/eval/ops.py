"""Jit'd wrappers wiring the fused evaluation + P2L kernels into the FMM.

``eval_fused_apply`` is the ``eval_fused_impl`` hook: it stages the dense
leaf planes once, issues exactly ONE ``pallas_call`` for the whole
evaluation phase (L2P + M2P + P2P with the phi tile VMEM-resident) and
scatters the result back to rank order — replacing the three separate
sweeps (and their three phi HBM round-trips) of the unfused path.

``p2l_apply`` is the ``p2l_impl`` hook for the downward pass: one
``pallas_call`` over (tile_boxes, P) local-coefficient blocks replacing
the ``p2l_sweep`` jnp scan.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ...core.config import FmmConfig
from ..common import (dense_leaf_arrays, dense_rank_planes, round_up,
                      scatter_from_leaves)
from .fused import eval_fused_pallas
from .p2l import p2l_pallas


def _coeff_planes(coeffs, P: int, rdt, extra_row: bool):
    """(nbox, p+1) complex -> real/imag (nbox[+1], P) planes, zero-padded."""
    pad = P - coeffs.shape[1]
    rows = (0, 1) if extra_row else (0, 0)
    br = jnp.pad(jnp.real(coeffs), (rows, (0, pad))).astype(rdt)
    bi = jnp.pad(jnp.imag(coeffs), (rows, (0, pad))).astype(rdt)
    return br, bi


def eval_fused_apply(local, mult_leaf, tree, conn, cfg: FmmConfig,
                     idx: np.ndarray, interpret: bool | None = None):
    """Drop-in ``eval_fused_impl`` for ``repro.core.fmm.fmm_evaluate``.

    local: (nbox, p+1) leaf local expansions; mult_leaf: (nbox, p+1) leaf
    multipoles (M2P sources). Returns the (n,) complex evaluation-phase
    potential (L2P + M2P + P2P) in rank order.
    """
    from ...core.fmm import effective_radii

    idx = np.asarray(idx)
    n_pad = round_up(idx.shape[1], 128)
    rdt = cfg.real_dtype
    zr, zi, qr, qi, _ = dense_leaf_arrays(tree.z, tree.q, idx, n_pad)
    rk = dense_rank_planes(idx, n_pad)

    c = tree.centers[cfg.nlevels]
    rho = effective_radii(tree, cfg)[cfg.nlevels]
    tr = ((zr[:-1] - jnp.real(c)[:, None]) / rho[:, None]).astype(rdt)
    ti = ((zi[:-1] - jnp.imag(c)[:, None]) / rho[:, None]).astype(rdt)

    P = round_up(cfg.p + 1, 128)
    br, bi = _coeff_planes(local, P, rdt, extra_row=False)

    kwargs = {}
    m2p_lists = None
    if cfg.use_p2l_m2p:
        m2p_lists = conn.m2p
        ar, ai = _coeff_planes(mult_leaf, P, rdt, extra_row=True)
        mask = m2p_lists >= 0
        src = jnp.where(mask, m2p_lists, 0)
        mcr = jnp.where(mask, jnp.real(c)[src], 0.0).astype(rdt)
        mci = jnp.where(mask, jnp.imag(c)[src], 0.0).astype(rdt)
        mrho = jnp.where(mask, rho[src], 0.0).astype(rdt)
        kwargs = {"ar": ar, "ai": ai, "mcr": mcr, "mci": mci, "mrho": mrho}

    outr, outi = eval_fused_pallas(
        conn.p2p, m2p_lists, zr[:-1], zi[:-1], rk[:-1], tr, ti, br, bi,
        zr, zi, qr, qi, rk, p=cfg.p, kernel=cfg.kernel,
        tile_boxes=cfg.tile_boxes, stage_width=cfg.stage_width,
        interpret=interpret, **kwargs)
    return scatter_from_leaves(outr + 1j * outi, idx, cfg.n)


def p2l_apply(tree, conn, cfg: FmmConfig, idx: np.ndarray, rho,
              interpret: bool | None = None):
    """Drop-in ``p2l_impl`` for the downward pass: returns the (nbox, p+1)
    complex radius-normalized P2L local-coefficient contribution (added
    to ``local`` by the caller)."""
    idx = np.asarray(idx)
    n_pad = round_up(idx.shape[1], 128)
    rdt = cfg.real_dtype
    zr, zi, qr, qi, _ = dense_leaf_arrays(tree.z, tree.q, idx, n_pad)
    c = tree.centers[cfg.nlevels]
    P = round_up(cfg.p + 1, 128)
    outr, outi = p2l_pallas(
        conn.p2l, jnp.real(c).astype(rdt), jnp.imag(c).astype(rdt),
        rho.astype(rdt), zr, zi, qr, qi, p=cfg.p, P=P, kernel=cfg.kernel,
        tile_boxes=cfg.tile_boxes, stage_width=cfg.stage_width,
        interpret=interpret)
    return (outr + 1j * outi)[:, : cfg.p + 1].astype(cfg.complex_dtype)

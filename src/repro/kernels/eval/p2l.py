"""Pallas TPU kernel: direct particle -> local-expansion shifts (P2L).

The Carrier-Greengard swapped-theta pairs at the leaf level route the
*larger* box's particles directly into the *smaller* box's local
expansion (paper §2). The reference implementation is a jnp scan over
list slots (``core/fmm.py:p2l_sweep``); this kernel is its Pallas twin
so the downward pass of the ``pallas`` backend no longer falls back to a
reference sweep.

Grid step = a tile of ``tile_boxes`` target boxes: the (TB, P)
local-coefficient output block stays resident in VMEM across the whole
p2l list; each step stages ``TB * stage_width`` source-box particle rows
(positions + strengths) through scalar-prefetch BlockSpecs. Per staged
row the kernel forms inv = 1/(x - z0_t) and w = rho_t * inv in vector
registers, runs the power recurrence over the p+1 coefficients and
lane-reduces each into its (TB, 1) output column. P2L lives in the
*downward* launch (not the evaluation megakernel) because its output is
local coefficients consumed by L2L/L2P — fusing it into evaluation would
re-introduce the HBM round-trip it exists to avoid (see DESIGN.md §2).
The grid is batch-major — (B, ntile, steps), ``program_id(0)`` selecting
the problem — so ``jax.vmap`` of ``p2l_pallas`` folds B problems into
one launch via the op's custom batching rule.

Both G-kernels: "harmonic" b~_l = rho^l sum q/(x-z0)^(l+1) and "log"
(b~_0 = sum q log(z0-x), b~_l = -rho^l sum q/(l (x-z0)^l)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import (compiler_params, make_batched_op, pad_boxes,
                      resolve_interpret, staged_list_specs)


def _make_kernel(p: int, P: int, kernel: str, TB: int, SW: int):
    n = TB * SW

    def body(lists_ref, z0r_ref, z0i_ref, rho_ref, *rest):
        xzr_refs, xzi_refs = rest[:n], rest[n:2 * n]
        xqr_refs, xqi_refs = rest[2 * n:3 * n], rest[3 * n:4 * n]
        outr, outi = rest[4 * n], rest[4 * n + 1]
        s = pl.program_id(2)

        @pl.when(s == 0)
        def _init():
            outr[...] = jnp.zeros_like(outr)
            outi[...] = jnp.zeros_like(outi)

        z0r = z0r_ref[...]                    # (TB, 1) target centers
        z0i = z0i_ref[...]
        rho = rho_ref[...]                    # (TB, 1) target radii

        def tile(refs, o):
            return jnp.concatenate([r[...] for r in refs[o:o + TB]], axis=0)

        for w in range(SW):
            o = w * TB
            xr, xi = tile(xzr_refs, o), tile(xzi_refs, o)   # (TB, n_pad)
            qr, qi = tile(xqr_refs, o), tile(xqi_refs, o)
            dxr = xr - z0r                    # x - z0_t
            dxi = xi - z0i
            d2 = dxr * dxr + dxi * dxi
            # d2 > 0 masks padded/dummy lanes (x = 0, q = 0) without a
            # staged validity plane; the cost is that a real source
            # particle EXACTLY at the target box center contributes 0
            # where the reference scan goes singular — a measure-zero
            # geometry (another box's particle at this box's
            # shrink-to-fit midpoint), accepted to keep the operand
            # count down.
            ok = d2 > 0.0
            k = jnp.where(ok, 1.0 / jnp.where(ok, d2, 1.0), 0.0)
            invr = dxr * k                    # 1 / (x - z0_t)
            invi = -dxi * k
            wr = rho * invr                   # rho_t / (x - z0_t)
            wi = rho * invi

            def red(a):                       # lane-reduce -> (TB, 1)
                return a.sum(axis=-1, keepdims=True)

            if kernel == "harmonic":
                pwr = qr * invr - qi * invi
                pwi = qr * invi + qi * invr
                cols_r, cols_i = [], []
                for _ in range(p + 1):
                    cols_r.append(red(pwr))
                    cols_i.append(red(pwi))
                    nr = pwr * wr - pwi * wi
                    ni = pwr * wi + pwi * wr
                    pwr, pwi = nr, ni
            else:
                # b~_0 = sum q log(z0 - x) = sum q log(-d)
                lr = jnp.where(ok, 0.5 * jnp.log(jnp.where(ok, d2, 1.0)),
                               0.0)
                li = jnp.where(ok, jnp.arctan2(-dxi, -dxr), 0.0)
                cols_r = [red(qr * lr - qi * li)]
                cols_i = [red(qr * li + qi * lr)]
                pwr = qr * wr - qi * wi
                pwi = qr * wi + qi * wr
                for l in range(1, p + 1):
                    cols_r.append(-red(pwr) / l)
                    cols_i.append(-red(pwi) / l)
                    nr = pwr * wr - pwi * wi
                    ni = pwr * wi + pwi * wr
                    pwr, pwi = nr, ni
            zpad = [jnp.zeros_like(cols_r[0])] * (P - p - 1)
            outr[...] += jnp.concatenate(cols_r + zpad, axis=1)
            outi[...] += jnp.concatenate(cols_i + zpad, axis=1)

    return body


@functools.partial(jax.jit, static_argnames=("p", "P", "kernel",
                                             "tile_boxes", "stage_width",
                                             "interpret"))
def _p2l_pallas(lists, z0r, z0i, rho, xzr, xzi, xqr, xqi, *, p: int, P: int,
                kernel: str, tile_boxes: int, stage_width: int,
                interpret: bool):
    """Batch-major core: lists (B, nbox, S), z0r/z0i/rho (B, nbox),
    particle planes (B, nbox+1, n_pad)."""
    B, nbox, _ = lists.shape
    n_pad = xzr.shape[-1]
    TB, SW = tile_boxes, stage_width
    dummy = xzr.shape[-2] - 1

    lists, src_specs, ntile = staged_list_specs(lists, dummy, TB, SW, n_pad)

    def col(a):
        return pad_boxes(a.reshape(B, -1, 1), ntile * TB)

    z0r, z0i, rho = col(z0r), col(z0i), col(rho)

    def tgt_map(b, i, s, lref):
        return (b, i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, ntile, lists.shape[-1] // SW),
        in_specs=[pl.BlockSpec((None, TB, 1), tgt_map)] * 3 + src_specs * 4,
        out_specs=[
            pl.BlockSpec((None, TB, P), tgt_map),
            pl.BlockSpec((None, TB, P), tgt_map),
        ],
    )
    dt = xzr.dtype
    n = TB * SW
    outr, outi = pl.pallas_call(
        _make_kernel(p, P, kernel, TB, SW),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, ntile * TB, P), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lists, z0r, z0i, rho, *([xzr] * n), *([xzi] * n), *([xqr] * n),
      *([xqi] * n))
    return outr[:, :nbox], outi[:, :nbox]


@functools.lru_cache(maxsize=None)
def _p2l_op(p: int, P: int, kernel: str, tile_boxes: int, stage_width: int,
            interpret: bool):
    """Per-problem P2L op; its custom batching rule lowers ``jax.vmap``
    onto the batch-major kernel grid (one launch for B problems)."""
    return make_batched_op(functools.partial(
        _p2l_pallas, p=p, P=P, kernel=kernel, tile_boxes=tile_boxes,
        stage_width=stage_width, interpret=interpret))


def p2l_pallas(lists, z0r, z0i, rho, xzr, xzi, xqr, xqi, *, p: int, P: int,
               kernel: str = "harmonic", tile_boxes: int = 8,
               stage_width: int = 1, interpret: bool | None = None):
    """lists: (nbox, S) int32 p2l list (-1 masked). z0r/z0i/rho: (nbox,)
    target-box center/radius; xzr/xzi/xqr/xqi: (nbox+1, n_pad) dense
    particle planes (dummy row zero). Returns (outr, outi): (nbox, P)
    radius-normalized local-coefficient contributions.
    ``interpret=None`` auto-selects from the JAX platform. Batch-native:
    under ``jax.vmap``, B problems compile to ONE batch-major launch.
    """
    op = _p2l_op(p, P, kernel, tile_boxes, stage_width,
                 resolve_interpret(interpret))
    return op(lists, z0r, z0i, rho, xzr, xzi, xqr, xqi)


def p2l_pallas_batched(lists, z0r, z0i, rho, xzr, xzi, xqr, xqi, *, p: int,
                       P: int, kernel: str = "harmonic", tile_boxes: int = 8,
                       stage_width: int = 1, interpret: bool | None = None):
    """Batch-major entry: all operands carry a leading problem axis B;
    one (B, ntile, steps) launch returns (B, nbox, P) planes."""
    return _p2l_pallas(lists, z0r, z0i, rho, xzr, xzi, xqr, xqi, p=p, P=P,
                       kernel=kernel, tile_boxes=tile_boxes,
                       stage_width=stage_width,
                       interpret=resolve_interpret(interpret))

"""Pallas TPU megakernel: the whole FMM evaluation phase in ONE launch.

The paper's evaluation phase (L2P + M2P + P2P; §3.3, ~56% of GPU runtime
in Table 5.1) previously ran as three device sweeps with ``phi`` making
three HBM round-trips: an L2P write, an M2P read-modify-write scan and a
P2P scatter-add. Cruz, Layton & Barba (arXiv:1009.3457) show the win for
FMM GPU kernels is keeping the *target tile resident* while every
interaction type accumulates into it; this kernel is that idea on TPU.

One grid step owns a tile of ``tile_boxes`` leaf boxes of one problem:
the grid is batch-major — (B, ntile, steps), ``program_id(0)`` selects
the problem — and the (TB, n_pad) ``phi`` output block stays resident in
VMEM across the entire fused interaction list and is written to HBM
exactly once:

  s == 0                 seed with the L2P Horner over the (TB, P) local
                         coefficient block (pre-centered particle planes);
  s <  p2p_steps         P2P: pairwise (TB, n_t, n_s) tile against staged
                         particle rows of the s-th strong-list slot;
  s >= p2p_steps         M2P: multipole Horner in w = rho_s/(z - z0_s)
                         against staged (1, P) multipole rows of the
                         (s - p2p_steps)-th m2p-list slot.

Both lists ride in ONE scalar-prefetch operand (``staged_multilist``):
the p2p region's columns select particle rows, the m2p region's columns
select multipole rows. Every staged spec family DMAs on every step — in
the foreign region it fetches a (harmless, valid) row that the
``pl.when`` branch never reads — which keeps the grid rectangular and
lets Pallas double-buffer all streams uniformly. B problems only
lengthen the batch-major grid axis — the per-step VMEM working set is
batch-invariant (``autotune.eval_fused_vmem_bytes`` stays valid), and
``jax.vmap`` of ``eval_fused_pallas`` lowers onto this grid through the
op's custom batching rule, so batched serving runs at kernel speed.

Self-interaction in the P2P branch is excluded by global particle rank
(trk/srk planes), not position, so duplicated positions keep their
(singular) mutual term. Both G-kernels: "harmonic" q/(z-x), "log"
q*log(z-x).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import (broadcast_unbatched, compiler_params, l2p_horner,
                      pad_boxes, pairwise_tile, prefetch_row_specs,
                      resolve_interpret, staged_multilist)


def _make_kernel(p: int, P: int, kernel: str, TB: int, SW: int,
                 p2p_steps: int, m2p_steps: int):
    n = TB * SW

    def body(lists_ref, tzr_ref, tzi_ref, trk_ref, tr_ref, ti_ref,
             br_ref, bi_ref, *rest):
        szr_refs, szi_refs = rest[:n], rest[n:2 * n]
        sqr_refs, sqi_refs = rest[2 * n:3 * n], rest[3 * n:4 * n]
        srk_refs = rest[4 * n:5 * n]
        if m2p_steps:
            ar_refs, ai_refs = rest[5 * n:6 * n], rest[6 * n:7 * n]
            mcr_ref, mci_ref, mrho_ref = rest[7 * n:7 * n + 3]
            outr, outi = rest[7 * n + 3], rest[7 * n + 4]
        else:
            outr, outi = rest[5 * n], rest[5 * n + 1]
        s = pl.program_id(2)

        def tile(refs, o):
            return jnp.concatenate([r[...] for r in refs[o:o + TB]], axis=0)

        @pl.when(s == 0)
        def _l2p():
            # seed phi with the local-expansion Horner: the L2P write
            # never leaves VMEM.
            outr[...], outi[...] = l2p_horner(p, br_ref, bi_ref,
                                              tr_ref[...], ti_ref[...])

        tzr = tzr_ref[...]                           # (TB, n_pad) targets
        tzi = tzi_ref[...]

        @pl.when(s < p2p_steps)
        def _p2p():
            trk = trk_ref[...]
            for w in range(SW):
                o = w * TB
                dr, di = pairwise_tile(kernel, tzr, tzi, trk,
                                       tile(szr_refs, o), tile(szi_refs, o),
                                       tile(sqr_refs, o), tile(sqi_refs, o),
                                       tile(srk_refs, o))
                outr[...] += dr
                outi[...] += di

        if m2p_steps:
            @pl.when(s >= p2p_steps)
            def _m2p():
                for w in range(SW):
                    o = w * TB
                    ar, ai = tile(ar_refs, o), tile(ai_refs, o)  # (TB, P)
                    cr = mcr_ref[:, w:w + 1]          # (TB, 1) slot planes
                    ci = mci_ref[:, w:w + 1]
                    rho = mrho_ref[:, w:w + 1]
                    dxr = tzr - cr                    # z - z0_src
                    dxi = tzi - ci
                    d2 = dxr * dxr + dxi * dxi
                    # gate on SLOT validity (masked slots carry rho = 0;
                    # effective radii are floored > 0), never on position:
                    # a target coinciding with the source center goes
                    # singular exactly like the reference sweep instead
                    # of silently dropping the contribution.
                    ok = rho > 0.0
                    k = jnp.where(ok, 1.0 / d2, 0.0)
                    wr = rho * dxr * k                # w = rho / (z - z0)
                    wi = -rho * dxi * k
                    accr = jnp.zeros_like(tzr) + ar[:, p:p + 1]
                    acci = jnp.zeros_like(tzi) + ai[:, p:p + 1]
                    for j in range(p - 1, 0, -1):
                        nr = accr * wr - acci * wi + ar[:, j:j + 1]
                        ni = accr * wi + acci * wr + ai[:, j:j + 1]
                        accr, acci = nr, ni
                    fr = accr * wr - acci * wi        # trailing * w (a_0 off)
                    fi = accr * wi + acci * wr
                    if kernel == "log":
                        # + a_0 * log(z - z0_src)
                        lr = jnp.where(ok, 0.5 * jnp.log(d2), 0.0)
                        li = jnp.where(ok, jnp.arctan2(dxi, dxr), 0.0)
                        a0r, a0i = ar[:, 0:1], ai[:, 0:1]
                        fr = fr + a0r * lr - a0i * li
                        fi = fi + a0r * li + a0i * lr
                    outr[...] += jnp.where(ok, fr, 0.0)
                    outi[...] += jnp.where(ok, fi, 0.0)

    return body


@functools.partial(jax.jit, static_argnames=("p", "kernel", "tile_boxes",
                                             "stage_width", "interpret"))
def _eval_fused_pallas(p2p_lists, m2p_lists, tzr, tzi, trk, tr, ti, br, bi,
                       szr, szi, sqr, sqi, srk, ar, ai, mcr, mci, mrho, *,
                       p: int, kernel: str, tile_boxes: int,
                       stage_width: int, interpret: bool):
    """Batch-major core: lists (B, nbox, S), planes (B, nbox[+1], ...).
    ``m2p_lists=None`` (with None multipole/slot planes) drops the M2P
    region entirely."""
    B, nbox, _ = p2p_lists.shape
    n_pad = tzr.shape[-1]
    TB, SW = tile_boxes, stage_width
    dummy = szr.shape[-2] - 1                # all-zero row in every plane
    with_m2p = m2p_lists is not None
    P = br.shape[-1]

    regions = [p2p_lists] + ([m2p_lists] if with_m2p else [])
    lists, ntile, steps = staged_multilist(regions, dummy, TB, SW)
    p2p_steps = steps[0]
    m2p_steps = steps[1] if with_m2p else 0

    def tgt(a, fill=0):
        return pad_boxes(a, ntile * TB, fill)

    tzr, tzi, tr, ti = tgt(tzr), tgt(tzi), tgt(tr), tgt(ti)
    br, bi, trk = tgt(br), tgt(bi), tgt(trk, -1)

    def tgt_map(b, i, s, lref):
        return (b, i, 0)

    def slot_map(b, i, s, lref):
        return (b, i, s)

    part_specs = prefetch_row_specs(TB, SW, n_pad)   # particle/rank rows
    in_specs = ([pl.BlockSpec((None, TB, n_pad), tgt_map)] * 5
                + [pl.BlockSpec((None, TB, P), tgt_map)] * 2
                + part_specs * 5)
    n = TB * SW
    operands = [lists, tzr, tzi, trk, tr, ti, br, bi,
                *([szr] * n), *([szi] * n), *([sqr] * n), *([sqi] * n),
                *([srk] * n)]
    if with_m2p:
        # slot planes span the whole fused list (zeros in the p2p region)
        total_cols = (p2p_steps + m2p_steps) * SW

        def slot_plane(a):
            a = jnp.pad(a, ((0, 0), (0, 0),
                            (p2p_steps * SW,
                             total_cols - p2p_steps * SW - a.shape[-1])))
            return tgt(a)

        mult_specs = prefetch_row_specs(TB, SW, P)   # multipole rows
        in_specs += (mult_specs * 2
                     + [pl.BlockSpec((None, TB, SW), slot_map)] * 3)
        operands += [*([ar] * n), *([ai] * n),
                     slot_plane(mcr), slot_plane(mci), slot_plane(mrho)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, ntile, p2p_steps + m2p_steps),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((None, TB, n_pad), tgt_map),
            pl.BlockSpec((None, TB, n_pad), tgt_map),
        ],
    )
    dt = tzr.dtype
    outr, outi = pl.pallas_call(
        _make_kernel(p, P, kernel, TB, SW, p2p_steps, m2p_steps),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, ntile * TB, n_pad), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return outr[:, :nbox], outi[:, :nbox]


@functools.lru_cache(maxsize=None)
def _eval_fused_op(p: int, kernel: str, tile_boxes: int, stage_width: int,
                   with_m2p: bool, interpret: bool):
    """Per-problem fused-evaluation op; its custom batching rule lowers
    ``jax.vmap`` onto the batch-major grid, so the evaluation phase of B
    problems is still exactly ONE launch. The ``with_m2p=False`` variant
    has no multipole/slot operands at all."""
    kw = dict(p=p, kernel=kernel, tile_boxes=tile_boxes,
              stage_width=stage_width, interpret=interpret)

    def call(args):
        if with_m2p:
            (p2p_lists, m2p_lists, tzr, tzi, trk, tr, ti, br, bi,
             szr, szi, sqr, sqi, srk, ar, ai, mcr, mci, mrho) = args
        else:
            (p2p_lists, tzr, tzi, trk, tr, ti, br, bi,
             szr, szi, sqr, sqi, srk) = args
            m2p_lists = ar = ai = mcr = mci = mrho = None
        return _eval_fused_pallas(p2p_lists, m2p_lists, tzr, tzi, trk, tr,
                                  ti, br, bi, szr, szi, sqr, sqi, srk,
                                  ar, ai, mcr, mci, mrho, **kw)

    @jax.custom_batching.custom_vmap
    def op(*args):
        outr, outi = call([a[None] for a in args])
        return outr[0], outi[0]

    @op.def_vmap
    def _rule(axis_size, in_batched, *args):
        return (call(broadcast_unbatched(args, in_batched, axis_size)),
                (True, True))

    return op


def eval_fused_pallas(p2p_lists, m2p_lists, tzr, tzi, trk, tr, ti, br, bi,
                      szr, szi, sqr, sqi, srk, ar=None, ai=None, mcr=None,
                      mci=None, mrho=None, *, p: int,
                      kernel: str = "harmonic", tile_boxes: int = 8,
                      stage_width: int = 1, interpret: bool | None = None):
    """One launch for the whole evaluation phase (L2P + M2P + P2P).

    p2p_lists/m2p_lists: (nbox, S) int32 leaf interaction lists (-1
    masked; ``m2p_lists=None`` drops the M2P region entirely — the
    ``use_p2l_m2p=False`` configuration). Dense planes: tzr/tzi absolute
    target positions, trk/srk int32 global ranks (-1 padded), tr/ti
    pre-centered normalized positions for the L2P Horner, br/bi (nbox, P)
    local-coefficient planes, szr/szi/sqr/sqi/srk (nbox+1, n_pad) source
    planes, ar/ai (nbox+1, P) leaf multipole planes, mcr/mci/mrho
    (nbox, S_m2p) per-slot source-center/radius planes (masked slots 0).

    Returns (outr, outi): (nbox, n_pad) — the full evaluation-phase
    potential at the dense leaf slots, written to HBM once. Batch-native:
    under ``jax.vmap``, B problems compile to ONE batch-major launch
    (see ``eval_fused_pallas_batched``).
    """
    with_m2p = m2p_lists is not None
    if with_m2p and (ar is None or mcr is None):
        raise ValueError("m2p region needs multipole and slot planes")
    op = _eval_fused_op(p, kernel, tile_boxes, stage_width, with_m2p,
                        resolve_interpret(interpret))
    args = (p2p_lists,)
    if with_m2p:
        args += (m2p_lists,)
    args += (tzr, tzi, trk, tr, ti, br, bi, szr, szi, sqr, sqi, srk)
    if with_m2p:
        args += (ar, ai, mcr, mci, mrho)
    return op(*args)


def eval_fused_pallas_batched(p2p_lists, m2p_lists, tzr, tzi, trk, tr, ti,
                              br, bi, szr, szi, sqr, sqi, srk, ar=None,
                              ai=None, mcr=None, mci=None, mrho=None, *,
                              p: int, kernel: str = "harmonic",
                              tile_boxes: int = 8, stage_width: int = 1,
                              interpret: bool | None = None):
    """Batch-major entry: all operands carry a leading problem axis B;
    one (B, ntile, steps) launch returns (B, nbox, n_pad) planes."""
    if m2p_lists is not None and (ar is None or mcr is None):
        raise ValueError("m2p region needs multipole and slot planes")
    return _eval_fused_pallas(
        p2p_lists, m2p_lists, tzr, tzi, trk, tr, ti, br, bi,
        szr, szi, sqr, sqi, srk, ar, ai, mcr, mci, mrho,
        p=p, kernel=kernel, tile_boxes=tile_boxes, stage_width=stage_width,
        interpret=resolve_interpret(interpret))

"""Pure-jnp oracles for the fused evaluation kernels.

The end-to-end oracle for ``eval_fused_apply`` is the unfused core path
(``l2p`` + ``m2p_sweep`` + ``p2p_sweep``), which the parity tests use
directly; ``m2p_ref`` is the dense-plane oracle for the megakernel's M2P
branch in isolation.
"""
from __future__ import annotations

import jax.numpy as jnp


def m2p_ref(lists, tzr, tzi, ar, ai, mcr, mci, mrho, p: int,
            kernel: str = "harmonic"):
    """Dense-plane M2P: Horner in w = rho_s/(z - z0_s) per list slot.

    lists: (nbox, S) int32 (-1 masked); tzr/tzi: (nbox, n_pad) targets;
    ar/ai: (nbox+1, P) multipole planes (dummy row zero); mcr/mci/mrho:
    (nbox, S) per-slot source center/radius planes (masked slots zero).
    Returns (outr, outi): (nbox, n_pad).
    """
    dummy = ar.shape[0] - 1
    srcs = jnp.where(lists >= 0, lists, dummy)
    a = (ar + 1j * ai)[srcs]                   # (nbox, S, P)
    tz = tzr + 1j * tzi
    dz = tz[:, None, :] - (mcr + 1j * mci)[..., None]   # (nbox, S, n_pad)
    # slot-validity gate (masked slots carry rho = 0); a target at the
    # source center goes singular, as in the core m2p_sweep
    ok = (mrho > 0)[..., None]
    w = jnp.where(ok, mrho[..., None] / dz, 0.0)
    acc = jnp.zeros_like(w) + a[..., p:p + 1]
    for j in range(p - 1, 0, -1):
        acc = acc * w + a[..., j:j + 1]
    acc = acc * w
    if kernel == "log":
        acc = acc + a[..., 0:1] * jnp.where(
            ok, jnp.log(jnp.where(ok, dz, 1.0)), 0.0)
    phi = jnp.where(ok, acc, 0.0).sum(axis=1)
    return jnp.real(phi), jnp.imag(phi)

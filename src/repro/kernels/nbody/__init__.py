from .nbody import nbody_pallas
from .ops import nbody_direct
from .ref import nbody_ref

__all__ = ["nbody_pallas", "nbody_direct", "nbody_ref"]

"""Pallas TPU kernel: direct N-body summation (paper Figs 5.5/5.6 baseline).

Classic tiled all-pairs: targets tiled on the parallel grid axis, sources
streamed tile-by-tile on the arbitrary axis with the (T, S) pairwise block
evaluated in registers. This is the paper's 'task for which GPUs are
generally understood to be well suited' — it bounds the achievable speedup
of the full FMM (their direct speedup 15x vs FMM 11x; here it realizes
the compute roofline, see benchmarks/fig5_5.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ..common import compiler_params, resolve_interpret


def _nbody_kernel(tzr, tzi, szr, szi, sqr, sqi, outr, outi):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        outr[...] = jnp.zeros_like(outr)
        outi[...] = jnp.zeros_like(outi)

    dx = szr[0][None, :] - tzr[0][:, None]
    dy = szi[0][None, :] - tzi[0][:, None]
    denom = dx * dx + dy * dy
    ok = denom > 0.0
    inv = jnp.where(ok, 1.0 / jnp.where(ok, denom, 1.0), 0.0)
    qr = sqr[0][None, :]
    qi = sqi[0][None, :]
    outr[...] += ((qr * dx + qi * dy) * inv).sum(axis=1)[None, :]
    outi[...] += ((qi * dx - qr * dy) * inv).sum(axis=1)[None, :]


@functools.partial(jax.jit, static_argnames=("t_tile", "s_tile", "interpret"))
def _nbody_pallas(tzr, tzi, szr, szi, sqr, sqi, *, t_tile: int,
                  s_tile: int, interpret: bool):
    nt = tzr.shape[0] // t_tile
    ns = szr.shape[0] // s_tile

    def tmap(i, j):
        return (i, 0)

    def smap(i, j):
        return (j, 0)

    dt = tzr.dtype
    r2 = lambda a, n: a.reshape(-1, n)
    outr, outi = pl.pallas_call(
        _nbody_kernel,
        grid=(nt, ns),
        in_specs=[
            pl.BlockSpec((1, t_tile), tmap),
            pl.BlockSpec((1, t_tile), tmap),
            pl.BlockSpec((1, s_tile), smap),
            pl.BlockSpec((1, s_tile), smap),
            pl.BlockSpec((1, s_tile), smap),
            pl.BlockSpec((1, s_tile), smap),
        ],
        out_specs=[
            pl.BlockSpec((1, t_tile), tmap),
            pl.BlockSpec((1, t_tile), tmap),
        ],
        out_shape=[jax.ShapeDtypeStruct((nt, t_tile), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r2(tzr, t_tile), r2(tzi, t_tile), r2(szr, s_tile), r2(szi, s_tile),
      r2(sqr, s_tile), r2(sqi, s_tile))
    return outr.reshape(-1), outi.reshape(-1)


def nbody_pallas(tzr, tzi, szr, szi, sqr, sqi, *, t_tile: int = 256,
                 s_tile: int = 512, interpret: bool | None = None):
    """All planes are 1-D (padded); returns (outr, outi) at target points.
    ``interpret=None`` auto-selects from the JAX platform."""
    return _nbody_pallas(tzr, tzi, szr, szi, sqr, sqi, t_tile=t_tile,
                         s_tile=s_tile, interpret=resolve_interpret(interpret))

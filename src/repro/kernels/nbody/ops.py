"""Jit'd wrapper: direct potential via the tiled Pallas N-body kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ..common import default_interpret, round_up


def nbody_direct(z_eval, z_src, q, *, t_tile: int = 256, s_tile: int = 512,
                 interpret: bool | None = None):
    """Phi(y_i) = sum_{x_j != y_i} q_j/(x_j - y_i); returns (n,) complex."""
    from .nbody import nbody_pallas

    if interpret is None:
        interpret = default_interpret()
    n = z_eval.shape[0]
    m = z_src.shape[0]
    npad = round_up(n, t_tile)
    mpad = round_up(m, s_tile)
    dt = jnp.real(z_src).dtype

    def pad(a, k):
        return jnp.pad(a, (0, k - a.shape[0]))

    tzr = pad(jnp.real(z_eval).astype(dt), npad)
    tzi = pad(jnp.imag(z_eval).astype(dt), npad)
    szr = pad(jnp.real(z_src).astype(dt), mpad)
    szi = pad(jnp.imag(z_src).astype(dt), mpad)
    sqr = pad(jnp.real(q).astype(dt), mpad)
    sqi = pad(jnp.imag(q).astype(dt), mpad)
    # padded sources sit at (0,0) with q=0 -> contribute nothing
    outr, outi = nbody_pallas(tzr, tzi, szr, szi, sqr, sqi, t_tile=t_tile,
                              s_tile=s_tile, interpret=interpret)
    return (outr + 1j * outi)[:n]

"""Pure-jnp oracle for the direct N-body kernel."""
from __future__ import annotations

import jax.numpy as jnp


def nbody_ref(tzr, tzi, szr, szi, sqr, sqi):
    tz = tzr + 1j * tzi
    sz = szr + 1j * szi
    sq = sqr + 1j * sqi
    diff = sz[None, :] - tz[:, None]
    ok = diff != 0
    phi = jnp.where(ok, sq[None, :] / jnp.where(ok, diff, 1.0), 0.0).sum(-1)
    return jnp.real(phi), jnp.imag(phi)

"""Pallas TPU kernel: M2L translation sweep (the paper's Algorithm 3.6).

The CUDA implementation runs the scaled-Horner shift with two threads per
shift in shared memory, one block owning all shifts of a target box (no f64
atomics on Fermi). On TPU we use the factorized form (DESIGN.md §2):

    local += diag((-1/r)^l) · H · diag(r^-k) · mult[src],
    H[l,k] = C(l+k-1, k-1)   (constant Hankel-binomial matrix)

so the inner operation per weak-list slot is a (TB,P)x(P,P) GEMM on the
MXU — a grid step owns a *tile* of ``tile_boxes`` target boxes, so the
contraction runs on full multi-sublane register tiles instead of rank-1
rows — plus two O(p) diagonal scalings computed as in-register column
recurrences (the paper's pre/post-scaling phases, verbatim). Source
coefficient rows are DMA'd HBM->VMEM through scalar-prefetch indexed
BlockSpecs driven by the weak interaction list (``stage_width`` slots per
step, double-buffered by Pallas); accumulation happens in the revisited
(TB, P) output block across the list axis — deterministic, in contrast to
the atomics the paper had to design around.

The box axis is *level-agnostic*: callers may flatten all levels of the
downward pass into one (sum 4^l, W) call with statically offset lists
(see ops.m2l_fused_apply), collapsing L launches into one. The grid is
additionally *batch-major* — (B, ntile, steps) with ``program_id(0)``
selecting the problem — so ``jax.vmap`` of ``m2l_pallas`` folds B
problems into the same single launch (custom batching rule; the Hankel
matrix stays one shared (P, P) constant across the batch).

Both G-kernels: "harmonic" (a_0 = 0, as in all of the paper's
experiments) and "log" (a_0 carries the source strength; the extra
a_0·log r term rides in as precomputed log-plane columns).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import (broadcast_unbatched, compiler_params, pad_boxes,
                      resolve_interpret, round_up, staged_list_specs)


def _make_kernel(p: int, P: int, kernel: str, TB: int, SW: int):
    n = TB * SW

    def body(weak_ref, *rest):
        ar_refs, ai_refs = rest[:n], rest[n:2 * n]
        prer_ref, prei_ref, postr_ref, posti_ref = rest[2 * n:2 * n + 4]
        if kernel == "log":
            logr_ref, logi_ref, ht_ref = rest[2 * n + 4:2 * n + 7]
            outr, outi = rest[2 * n + 7], rest[2 * n + 8]
        else:
            ht_ref = rest[2 * n + 4]
            outr, outi = rest[2 * n + 5], rest[2 * n + 6]
        s = pl.program_id(2)

        @pl.when(s == 0)
        def _init():
            outr[...] = jnp.zeros_like(outr)
            outi[...] = jnp.zeros_like(outi)

        def col_pows(br, bi):
            # [(br+i bi)^k for k=0..p] as (TB, P) planes, zero-padded
            rs, is_ = [jnp.ones_like(br)], [jnp.zeros_like(bi)]
            for _ in range(p):
                nr = rs[-1] * br - is_[-1] * bi
                ni = rs[-1] * bi + is_[-1] * br
                rs.append(nr)
                is_.append(ni)
            zpad = [jnp.zeros_like(br)] * (P - p - 1)
            return (jnp.concatenate(rs + zpad, axis=1),
                    jnp.concatenate(is_ + zpad, axis=1))

        ht = ht_ref[...]
        for w in range(SW):
            o = w * TB
            ar = jnp.concatenate([r[...] for r in ar_refs[o:o + TB]], axis=0)
            ai = jnp.concatenate([r[...] for r in ai_refs[o:o + TB]], axis=0)
            # bounded ratio scale factors (radius-normalized coefficients):
            pr, pi = col_pows(prer_ref[:, w:w + 1], prei_ref[:, w:w + 1])
            mr, mi = col_pows(postr_ref[:, w:w + 1], posti_ref[:, w:w + 1])
            ahr = ar * pr - ai * pi
            ahi = ar * pi + ai * pr
            dt = ar.dtype
            bhr = jnp.dot(ahr, ht, preferred_element_type=dt)
            bhi = jnp.dot(ahi, ht, preferred_element_type=dt)
            outr[...] += bhr * mr - bhi * mi
            outi[...] += bhr * mi + bhi * mr
            if kernel == "log":
                # b_0 += a_0 * log(r) (source strength rides in a_0)
                a0r, a0i = ar[:, 0:1], ai[:, 0:1]
                lr = logr_ref[:, w:w + 1]
                li = logi_ref[:, w:w + 1]
                col0 = jax.lax.broadcasted_iota(jnp.int32, (TB, P), 1) == 0
                outr[...] += jnp.where(col0, a0r * lr - a0i * li, 0.0)
                outi[...] += jnp.where(col0, a0r * li + a0i * lr, 0.0)

    return body


@functools.partial(jax.jit, static_argnames=("p", "kernel", "tile_boxes",
                                             "stage_width", "interpret"))
def _m2l_pallas(weak: jax.Array, ar, ai, prer, prei, postr, posti, logr,
                logi, ht, *, p: int, kernel: str, tile_boxes: int,
                stage_width: int, interpret: bool):
    """Batch-major core: weak (B, nbox, W), coefficient planes
    (B, nbox+1, P), ratio planes (B, nbox, W); ht one shared (P, P)."""
    B, nbox, W = weak.shape
    P = ar.shape[-1]
    TB, SW = tile_boxes, stage_width
    W_pad = round_up(W, SW)
    dummy = ar.shape[-2] - 1

    weak, src_specs, ntile = staged_list_specs(weak, dummy, TB, SW, P)

    def plane(a):
        a = pad_boxes(a, ntile * TB)
        return jnp.pad(a, ((0, 0), (0, 0), (0, W_pad - W)))

    planes = [plane(a) for a in (prer, prei, postr, posti)]
    if kernel == "log":
        planes += [plane(logr), plane(logi)]

    def tgt_map(b, i, s, wref):
        return (b, i, 0)

    def slot_map(b, i, s, wref):
        return (b, i, s)

    def const_map(b, i, s, wref):
        return (0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, ntile, W_pad // SW),
        in_specs=(src_specs * 2
                  + [pl.BlockSpec((None, TB, SW), slot_map)] * len(planes)
                  + [pl.BlockSpec((P, P), const_map)]),
        out_specs=[
            pl.BlockSpec((None, TB, P), tgt_map),
            pl.BlockSpec((None, TB, P), tgt_map),
        ],
    )
    dt = ar.dtype
    n = TB * SW
    outr, outi = pl.pallas_call(
        _make_kernel(p, P, kernel, TB, SW),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, ntile * TB, P), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(weak, *([ar] * n), *([ai] * n), *planes, ht)
    return outr[:, :nbox], outi[:, :nbox]


@functools.lru_cache(maxsize=None)
def _m2l_op(p: int, kernel: str, tile_boxes: int, stage_width: int,
            interpret: bool):
    """Per-problem M2L op; its custom batching rule lowers ``jax.vmap``
    onto the batch-major grid. The log variant carries two extra log(r)
    plane operands; the Hankel matrix ``ht`` is a shared constant and is
    never broadcast along the batch."""
    kw = dict(p=p, kernel=kernel, tile_boxes=tile_boxes,
              stage_width=stage_width, interpret=interpret)
    with_log = kernel == "log"

    def call(weak, ar, ai, prer, prei, postr, posti, logr, logi, ht):
        return _m2l_pallas(weak, ar, ai, prer, prei, postr, posti, logr,
                           logi, ht, **kw)

    def split(args):
        # ht is always last; the log planes precede it on the log kernel
        if with_log:
            return args[:-3], args[-3:-1], args[-1]
        return args[:-1], (None, None), args[-1]

    def placeholder(ar):
        return jnp.zeros((), ar.dtype)

    @jax.custom_batching.custom_vmap
    def op(*args):
        batched, (logr, logi), ht = split(args)
        batched = [a[None] for a in batched]
        logs = ([logr[None], logi[None]] if with_log
                else [placeholder(args[1])] * 2)
        outr, outi = call(*batched, *logs, ht)
        return outr[0], outi[0]

    @op.def_vmap
    def _rule(axis_size, in_batched, *args):
        batched, logs, ht = split(args)
        bflags, lflags, htflag = split(in_batched)
        batched = broadcast_unbatched(batched, bflags, axis_size)
        if with_log:
            logs = broadcast_unbatched(logs, lflags, axis_size)
        else:
            logs = [placeholder(args[1])] * 2
        if htflag:
            # ht is the constant binomial matrix, shared across the
            # batch by construction — a per-problem ht cannot be
            # honored on the shared (P, P) kernel operand, so refuse
            # loudly rather than silently use one problem's matrix.
            raise ValueError(
                "m2l_pallas: the Hankel matrix ht must not carry the "
                "vmapped axis (it is one shared (P, P) constant); pass "
                "it unbatched")
        return call(*batched, *logs, ht), (True, True)

    return op


def m2l_pallas(weak: jax.Array, ar, ai, prer, prei, postr, posti, ht, *,
               p: int, kernel: str = "harmonic", logr=None, logi=None,
               tile_boxes: int = 8, stage_width: int = 1,
               interpret: bool | None = None):
    """weak: (nbox, W) int32 (-1 masked -> redirected to zero dummy row).

    ar/ai: (nbox+1, P) normalized multipole planes; prer/prei and
    postr/posti: (nbox, W) complex ratio planes (rho_s/r and -rho_t/r);
    ht: (P, P) transposed Hankel matrix; logr/logi: (nbox, W) log(r)
    planes (log kernel only). Returns (outr, outi) of shape (nbox, P) —
    the summed normalized local contributions per target box.
    ``interpret=None`` auto-selects from the JAX platform. Batch-native:
    under ``jax.vmap``, B problems compile to ONE batch-major launch.
    """
    if kernel == "log" and (logr is None or logi is None):
        raise ValueError("log kernel needs logr/logi planes")
    op = _m2l_op(p, kernel, tile_boxes, stage_width,
                 resolve_interpret(interpret))
    args = (weak, ar, ai, prer, prei, postr, posti)
    if kernel == "log":
        args += (logr, logi)
    return op(*args, ht)


def m2l_pallas_batched(weak: jax.Array, ar, ai, prer, prei, postr, posti,
                       ht, *, p: int, kernel: str = "harmonic", logr=None,
                       logi=None, tile_boxes: int = 8, stage_width: int = 1,
                       interpret: bool | None = None):
    """Batch-major entry: operands carry a leading problem axis B (``ht``
    stays one shared (P, P) constant); one (B, ntile, steps) launch."""
    if kernel == "log" and (logr is None or logi is None):
        raise ValueError("log kernel needs logr/logi planes")
    if logr is None:
        logr = logi = jnp.zeros((), ar.dtype)  # unused placeholder
    return _m2l_pallas(weak, ar, ai, prer, prei, postr, posti, logr, logi,
                       ht, p=p, kernel=kernel, tile_boxes=tile_boxes,
                       stage_width=stage_width,
                       interpret=resolve_interpret(interpret))

"""Pallas TPU kernel: M2L level sweep (the paper's Algorithm 3.6).

The CUDA implementation runs the scaled-Horner shift with two threads per
shift in shared memory, one block owning all shifts of a target box (no f64
atomics on Fermi). On TPU we use the factorized form (DESIGN.md §2):

    local += diag((-1/r)^l) · H · diag(r^-k) · mult[src],
    H[l,k] = C(l+k-1, k-1)   (constant Hankel-binomial matrix)

so the inner operation per (target, weak-list slot) is a (1,P)x(P,P) GEMM
on the MXU plus two O(p) diagonal scalings computed as in-register scalar
recurrences (the paper's pre/post-scaling phases, verbatim). Source
coefficient rows are DMA'd HBM->VMEM through a scalar-prefetch indexed
BlockSpec driven by the weak interaction list; accumulation happens in the
revisited output block across the s grid axis — deterministic, in contrast
to the atomics the paper had to design around.

Harmonic kernel only (a_0 = 0), as in all of the paper's experiments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import compiler_params


def _make_kernel(p: int, P: int):
    def kernel(weak_ref, ar_ref, ai_ref, prer_ref, prei_ref, postr_ref,
               posti_ref, ht_ref, outr, outi):
        s = pl.program_id(1)

        @pl.when(s == 0)
        def _init():
            outr[...] = jnp.zeros_like(outr)
            outi[...] = jnp.zeros_like(outi)

        def scalar_pows(br, bi):
            # [(br+i bi)^k for k=0..p], padded with zeros to length P
            out_r, out_i = [jnp.ones_like(br)], [jnp.zeros_like(bi)]
            for _ in range(p):
                nr = out_r[-1] * br - out_i[-1] * bi
                ni = out_r[-1] * bi + out_i[-1] * br
                out_r.append(nr)
                out_i.append(ni)
            zpad = [jnp.zeros_like(br)] * (P - p - 1)
            return (jnp.stack(out_r + zpad)[None, :],
                    jnp.stack(out_i + zpad)[None, :])

        # bounded ratio scale factors (radius-normalized coefficients):
        pr, pi = scalar_pows(prer_ref[0, s], prei_ref[0, s])   # (rho_s/r)^k
        mr, mi = scalar_pows(postr_ref[0, s], posti_ref[0, s])  # (-rho_t/r)^l

        ar = ar_ref[...]
        ai = ai_ref[...]
        ahr = ar * pr - ai * pi
        ahi = ar * pi + ai * pr
        dt = ar.dtype
        bhr = jnp.dot(ahr, ht_ref[...], preferred_element_type=dt)
        bhi = jnp.dot(ahi, ht_ref[...], preferred_element_type=dt)
        outr[...] += bhr * mr - bhi * mi
        outi[...] += bhr * mi + bhi * mr

    return kernel


@functools.partial(jax.jit, static_argnames=("p", "interpret"))
def m2l_pallas(weak: jax.Array, ar, ai, prer, prei, postr, posti, ht, *,
               p: int, interpret: bool = True):
    """weak: (nbox, W) int32 (-1 masked -> redirected to zero dummy row).

    ar/ai: (nbox+1, P) normalized multipole planes; prer/prei and
    postr/posti: (nbox, W) complex ratio planes (rho_s/r and -rho_t/r);
    ht: (P, P) transposed Hankel matrix. Returns (outr, outi) of shape
    (nbox, P) — the summed normalized local contributions of the level.
    """
    nbox, W = weak.shape
    P = ar.shape[1]
    dummy = ar.shape[0] - 1
    weak = jnp.where(weak >= 0, weak, dummy)

    def tgt_map(b, s, wref):
        return (b, 0)

    def src_map(b, s, wref):
        return (wref[b, s], 0)

    def const_map(b, s, wref):
        return (0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbox, W),
        in_specs=[
            pl.BlockSpec((1, P), src_map),    # ar
            pl.BlockSpec((1, P), src_map),    # ai
            pl.BlockSpec((1, W), tgt_map),    # pre (re)
            pl.BlockSpec((1, W), tgt_map),    # pre (im)
            pl.BlockSpec((1, W), tgt_map),    # post (re)
            pl.BlockSpec((1, W), tgt_map),    # post (im)
            pl.BlockSpec((P, P), const_map),  # ht
        ],
        out_specs=[
            pl.BlockSpec((1, P), tgt_map),
            pl.BlockSpec((1, P), tgt_map),
        ],
    )
    dt = ar.dtype
    return pl.pallas_call(
        _make_kernel(p, P),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((nbox, P), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(weak, ar, ai, prer, prei, postr, posti, ht)

"""Pure-jnp oracle for the M2L kernel (same dense-plane contract)."""
from __future__ import annotations

import jax.numpy as jnp


def m2l_ref(weak, ar, ai, prer, prei, postr, posti, ht,
            logr=None, logi=None):
    nbox, W = weak.shape
    P = ar.shape[1]
    dummy = ar.shape[0] - 1
    src = jnp.where(weak >= 0, weak, dummy)
    a = (ar + 1j * ai)[src]                  # (nbox, W, P)
    k = jnp.arange(P)
    pre = (prer + 1j * prei)[..., None] ** k     # (rho_s/r)^k
    post = (postr + 1j * posti)[..., None] ** k  # (-rho_t/r)^l
    b_hat = jnp.einsum("bwk,kl->bwl", a * pre, ht.astype(a.dtype))
    out = (b_hat * post).sum(axis=1)
    if logr is not None:
        # log kernel: b_0 += sum_w a_0 * log(r)
        out = out.at[:, 0].add((a[..., 0] * (logr + 1j * logi)).sum(axis=1))
    return jnp.real(out), jnp.imag(out)

from .m2l import m2l_pallas, m2l_pallas_batched
from .ops import fused_levels, m2l_fused_apply, m2l_level_apply
from .ref import m2l_ref

__all__ = ["m2l_pallas", "m2l_pallas_batched", "m2l_level_apply",
           "m2l_fused_apply", "fused_levels", "m2l_ref"]

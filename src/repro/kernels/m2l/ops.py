"""Jit'd wrapper wiring the M2L Pallas kernel into the FMM downward pass."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ...core import expansions as E
from ...core.config import FmmConfig
from ..common import default_interpret, round_up
from .m2l import m2l_pallas


def m2l_level_apply(mult, weak, centers, cfg: FmmConfig, rho,
                    interpret: bool | None = None):
    """Drop-in ``m2l_impl`` for ``repro.core.fmm.downward_with``.

    mult: (nbox, p+1) complex *radius-normalized* coefficients; weak:
    (nbox, W) int32; centers/rho: (nbox,). The pre/post scale factors
    (rho_s/r and -rho_t/r — bounded ratios, see expansions.py) are computed
    here as complex planes; the kernel runs the power recurrences on them.
    Returns (nbox, p+1) complex normalized local contributions.
    """
    if cfg.kernel != "harmonic":
        raise NotImplementedError("Pallas M2L implements the harmonic kernel")
    if interpret is None:
        interpret = default_interpret()
    nbox, W = weak.shape
    P = round_up(cfg.p + 1, 128)
    rdt = cfg.real_dtype

    pad = P - (cfg.p + 1)
    ar = jnp.pad(jnp.real(mult), ((0, 1), (0, pad))).astype(rdt)
    ai = jnp.pad(jnp.imag(mult), ((0, 1), (0, pad))).astype(rdt)

    mask = weak >= 0
    src = jnp.where(mask, weak, 0)
    r = jnp.where(mask, centers[:, None] - centers[src], 1.0)
    pre = jnp.where(mask, rho[src], 0.0) / r             # rho_s / r
    post = -rho[:, None] / r                             # -rho_t / r

    h = np.zeros((P, P))
    h[: cfg.p + 1, : cfg.p + 1] = E.m2l_matrix(cfg.p)
    ht = jnp.asarray(h.T, dtype=rdt)

    outr, outi = m2l_pallas(
        weak, ar, ai,
        jnp.real(pre).astype(rdt), jnp.imag(pre).astype(rdt),
        jnp.real(post).astype(rdt), jnp.imag(post).astype(rdt),
        ht, p=cfg.p, interpret=interpret)
    return (outr + 1j * outi)[:, : cfg.p + 1].astype(mult.dtype)

"""Jit'd wrappers wiring the M2L Pallas kernel into the FMM downward pass.

Two entry points share one kernel:

  m2l_level_apply  — one level (the ``m2l_impl`` per-level hook contract);
  m2l_fused_apply  — *all* levels of the downward pass flattened into a
                     single (sum 4^l, W) kernel call with static per-level
                     offsets (the ``m2l_fused_impl`` hook), replacing L
                     separate launches: each level's M2L depends only on
                     the upward pass, so the whole sweep is one grid.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ...core import expansions as E
from ...core.config import FmmConfig
from ..common import round_up
from .m2l import m2l_pallas


def _hankel_t(cfg: FmmConfig, P: int):
    h = np.zeros((P, P))
    h[: cfg.p + 1, : cfg.p + 1] = E.m2l_matrix(cfg.p)
    return jnp.asarray(h.T, dtype=cfg.real_dtype)


def _m2l_call(mult, weak, centers, cfg: FmmConfig, rho, interpret):
    """One kernel invocation over a (level-agnostic) flat box axis.

    mult: (nbox, p+1) complex *radius-normalized* coefficients; weak:
    (nbox, W) int32; centers/rho: (nbox,). The pre/post scale factors
    (rho_s/r and -rho_t/r — bounded ratios, see expansions.py) are computed
    here as complex planes; the kernel runs the power recurrences on them.
    Returns (nbox, p+1) complex normalized local contributions.
    """
    P = round_up(cfg.p + 1, 128)
    rdt = cfg.real_dtype

    pad = P - (cfg.p + 1)
    ar = jnp.pad(jnp.real(mult), ((0, 1), (0, pad))).astype(rdt)
    ai = jnp.pad(jnp.imag(mult), ((0, 1), (0, pad))).astype(rdt)

    mask = weak >= 0
    src = jnp.where(mask, weak, 0)
    r = jnp.where(mask, centers[:, None] - centers[src], 1.0)
    pre = jnp.where(mask, rho[src], 0.0) / r             # rho_s / r
    post = -rho[:, None] / r                             # -rho_t / r

    kwargs = {}
    if cfg.kernel == "log":
        logr = jnp.log(r)                                # masked slots: log 1
        kwargs = {"logr": jnp.real(logr).astype(rdt),
                  "logi": jnp.imag(logr).astype(rdt)}

    outr, outi = m2l_pallas(
        weak, ar, ai,
        jnp.real(pre).astype(rdt), jnp.imag(pre).astype(rdt),
        jnp.real(post).astype(rdt), jnp.imag(post).astype(rdt),
        _hankel_t(cfg, P), p=cfg.p, kernel=cfg.kernel,
        tile_boxes=cfg.tile_boxes, stage_width=cfg.stage_width,
        interpret=interpret, **kwargs)
    return (outr + 1j * outi)[:, : cfg.p + 1].astype(mult.dtype)


def m2l_level_apply(mult, weak, centers, cfg: FmmConfig, rho,
                    interpret: bool | None = None):
    """Drop-in ``m2l_impl`` for ``repro.core.fmm.downward_with``."""
    return _m2l_call(mult, weak, centers, cfg, rho, interpret)


def fused_levels(cfg: FmmConfig) -> list[int]:
    """Levels the fused downward M2L covers (1..L; just the root if L=0)."""
    return list(range(1, cfg.nlevels + 1)) if cfg.nlevels > 0 else [0]


def m2l_fused_apply(mult, weak, centers, cfg: FmmConfig, rho,
                    interpret: bool | None = None):
    """Drop-in ``m2l_fused_impl`` for ``repro.core.fmm.downward_fused``.

    mult/weak/centers/rho are the *per-level* sequences (index = level).
    Concatenates every level's boxes into one flat axis — the weak lists
    are level-local, so each level's entries are shifted by its static
    offset — and issues exactly one ``pallas_call`` for the whole
    downward M2L. Returns the per-level (4**l, p+1) contributions.
    """
    levels = fused_levels(cfg)
    offs = np.concatenate([[0], np.cumsum([4**l for l in levels])])
    weak_flat = jnp.concatenate(
        [jnp.where(weak[l] >= 0, weak[l] + int(offs[i]), -1)
         for i, l in enumerate(levels)], axis=0)
    mult_flat = jnp.concatenate([mult[l] for l in levels], axis=0)
    centers_flat = jnp.concatenate([centers[l] for l in levels])
    rho_flat = jnp.concatenate([rho[l] for l in levels])
    out = _m2l_call(mult_flat, weak_flat, centers_flat, cfg, rho_flat,
                    interpret)
    return [out[int(offs[i]): int(offs[i + 1])] for i in range(len(levels))]

"""Pure-jnp oracle for the L2P kernel."""
from __future__ import annotations

import jax.numpy as jnp


def l2p_ref(br, bi, tr, ti, p: int):
    b = br + 1j * bi                       # (nbox, P)
    t = tr + 1j * ti                       # (nbox, n_pad)
    acc = jnp.zeros_like(t) + b[:, p][:, None]
    for j in range(p - 1, -1, -1):
        acc = acc * t + b[:, j][:, None]
    return jnp.real(acc), jnp.imag(acc)

from .l2p import l2p_pallas, l2p_pallas_batched
from .ops import l2p_apply
from .ref import l2p_ref

__all__ = ["l2p_pallas", "l2p_pallas_batched", "l2p_apply", "l2p_ref"]

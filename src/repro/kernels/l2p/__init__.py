from .l2p import l2p_pallas
from .ops import l2p_apply
from .ref import l2p_ref

__all__ = ["l2p_pallas", "l2p_apply", "l2p_ref"]

"""Jit'd wrapper wiring the L2P Pallas kernel into the FMM evaluation."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ...core.config import FmmConfig
from ..common import dense_leaf_arrays, round_up, scatter_from_leaves
from .l2p import l2p_pallas


def l2p_apply(local, tree, cfg: FmmConfig, idx: np.ndarray,
              interpret: bool | None = None):
    """Evaluate leaf local expansions; returns (n,) complex in rank order."""
    idx = np.asarray(idx)
    n_pad = round_up(idx.shape[1], 128)
    rdt = cfg.real_dtype
    zr, zi, _, _, valid = dense_leaf_arrays(tree.z, tree.q, idx, n_pad)
    zr, zi, valid = zr[:-1], zi[:-1], valid[:-1]
    c = tree.centers[cfg.nlevels]
    from ...core.fmm import effective_radii
    rho = effective_radii(tree, cfg)[cfg.nlevels]
    tr = ((zr - jnp.real(c)[:, None]) / rho[:, None]).astype(rdt)
    ti = ((zi - jnp.imag(c)[:, None]) / rho[:, None]).astype(rdt)

    P = round_up(cfg.p + 1, 128)
    pad = P - (cfg.p + 1)
    br = jnp.pad(jnp.real(local), ((0, 0), (0, pad))).astype(rdt)
    bi = jnp.pad(jnp.imag(local), ((0, 0), (0, pad))).astype(rdt)

    outr, outi = l2p_pallas(br, bi, tr, ti, p=cfg.p,
                            tile_boxes=cfg.tile_boxes, interpret=interpret)
    out = jnp.where(valid, outr + 1j * outi, 0.0)
    return scatter_from_leaves(out, idx, cfg.n)

"""Pallas TPU kernel: local evaluation (L2P) at leaf particles.

One grid step per leaf box: the (1, P) local-coefficient block and the
(1, n_pad) pre-centered particle tile live in VMEM; the p-term Horner
recurrence runs on full vector registers with the coefficients read as
scalars (static lane indices). The paper uses one thread per evaluation
point with 64 threads/block; the TPU analogue is the 8x128 vector lane
grid processing the whole box at once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import compiler_params


def _make_kernel(p: int):
    def kernel(br_ref, bi_ref, tr_ref, ti_ref, outr, outi):
        tr = tr_ref[...]
        ti = ti_ref[...]
        accr = jnp.full_like(tr, 0.0) + br_ref[0, p]
        acci = jnp.full_like(ti, 0.0) + bi_ref[0, p]
        for j in range(p - 1, -1, -1):
            nr = accr * tr - acci * ti + br_ref[0, j]
            ni = accr * ti + acci * tr + bi_ref[0, j]
            accr, acci = nr, ni
        outr[...] = accr
        outi[...] = acci

    return kernel


@functools.partial(jax.jit, static_argnames=("p", "interpret"))
def l2p_pallas(br, bi, tr, ti, *, p: int, interpret: bool = True):
    """br/bi: (nbox, P) local planes; tr/ti: (nbox, n_pad) pre-centered
    particle planes (z - z0). Returns (outr, outi): (nbox, n_pad)."""
    nbox, P = br.shape
    n_pad = tr.shape[1]

    def row(b):
        return (b, 0)

    dt = tr.dtype
    return pl.pallas_call(
        _make_kernel(p),
        grid=(nbox,),
        in_specs=[
            pl.BlockSpec((1, P), row),
            pl.BlockSpec((1, P), row),
            pl.BlockSpec((1, n_pad), row),
            pl.BlockSpec((1, n_pad), row),
        ],
        out_specs=[
            pl.BlockSpec((1, n_pad), row),
            pl.BlockSpec((1, n_pad), row),
        ],
        out_shape=[jax.ShapeDtypeStruct((nbox, n_pad), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(br, bi, tr, ti)

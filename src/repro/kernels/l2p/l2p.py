"""Pallas TPU kernel: local evaluation (L2P) at leaf particles.

One grid step per *tile* of ``tile_boxes`` leaf boxes: the (TB, P)
local-coefficient block and the (TB, n_pad) pre-centered particle tile
live in VMEM; the p-term Horner recurrence runs on full multi-sublane
vector registers with the coefficients read as per-row columns (static
lane indices). The paper uses one thread per evaluation point with 64
threads/block; the TPU analogue is the 8x128 vector lane grid processing
``tile_boxes`` whole boxes at once (DESIGN.md §2). The grid is
batch-major — (B, ntile) with ``program_id(0)`` selecting the problem —
so ``jax.vmap`` of ``l2p_pallas`` folds B problems into one launch.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from ..common import (compiler_params, l2p_horner, make_batched_op,
                      pad_boxes, resolve_interpret)


def _make_kernel(p: int):
    def kernel(br_ref, bi_ref, tr_ref, ti_ref, outr, outi):
        outr[...], outi[...] = l2p_horner(p, br_ref, bi_ref,
                                          tr_ref[...], ti_ref[...])

    return kernel


@functools.partial(jax.jit, static_argnames=("p", "tile_boxes", "interpret"))
def _l2p_pallas(br, bi, tr, ti, *, p: int, tile_boxes: int, interpret: bool):
    """Batch-major core: br/bi (B, nbox, P), tr/ti (B, nbox, n_pad)."""
    B, nbox, P = br.shape
    n_pad = tr.shape[-1]
    TB = tile_boxes
    ntile = -(-nbox // TB)
    br, bi = pad_boxes(br, ntile * TB), pad_boxes(bi, ntile * TB)
    tr, ti = pad_boxes(tr, ntile * TB), pad_boxes(ti, ntile * TB)

    def row(b, i):
        return (b, i, 0)

    dt = tr.dtype
    outr, outi = pl.pallas_call(
        _make_kernel(p),
        grid=(B, ntile),
        in_specs=[
            pl.BlockSpec((None, TB, P), row),
            pl.BlockSpec((None, TB, P), row),
            pl.BlockSpec((None, TB, n_pad), row),
            pl.BlockSpec((None, TB, n_pad), row),
        ],
        out_specs=[
            pl.BlockSpec((None, TB, n_pad), row),
            pl.BlockSpec((None, TB, n_pad), row),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, ntile * TB, n_pad), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(br, bi, tr, ti)
    return outr[:, :nbox], outi[:, :nbox]


@functools.lru_cache(maxsize=None)
def _l2p_op(p: int, tile_boxes: int, interpret: bool):
    """Per-problem L2P op; its custom batching rule lowers ``jax.vmap``
    onto the batch-major grid."""
    return make_batched_op(functools.partial(
        _l2p_pallas, p=p, tile_boxes=tile_boxes, interpret=interpret))


def l2p_pallas(br, bi, tr, ti, *, p: int, tile_boxes: int = 8,
               interpret: bool | None = None):
    """br/bi: (nbox, P) local planes; tr/ti: (nbox, n_pad) pre-centered
    particle planes (z - z0). Returns (outr, outi): (nbox, n_pad).
    ``interpret=None`` auto-selects from the JAX platform. Batch-native:
    under ``jax.vmap``, B problems compile to ONE batch-major launch."""
    return _l2p_op(p, tile_boxes, resolve_interpret(interpret))(br, bi,
                                                                tr, ti)


def l2p_pallas_batched(br, bi, tr, ti, *, p: int, tile_boxes: int = 8,
                       interpret: bool | None = None):
    """Batch-major entry: operands carry a leading problem axis B; one
    (B, ntile) launch returns (B, nbox, n_pad) planes."""
    return _l2p_pallas(br, bi, tr, ti, p=p, tile_boxes=tile_boxes,
                       interpret=resolve_interpret(interpret))

"""Pallas TPU kernel: local evaluation (L2P) at leaf particles.

One grid step per *tile* of ``tile_boxes`` leaf boxes: the (TB, P)
local-coefficient block and the (TB, n_pad) pre-centered particle tile
live in VMEM; the p-term Horner recurrence runs on full multi-sublane
vector registers with the coefficients read as per-row columns (static
lane indices). The paper uses one thread per evaluation point with 64
threads/block; the TPU analogue is the 8x128 vector lane grid processing
``tile_boxes`` whole boxes at once (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import compiler_params, l2p_horner, pad_rows, resolve_interpret


def _make_kernel(p: int):
    def kernel(br_ref, bi_ref, tr_ref, ti_ref, outr, outi):
        outr[...], outi[...] = l2p_horner(p, br_ref, bi_ref,
                                          tr_ref[...], ti_ref[...])

    return kernel


@functools.partial(jax.jit, static_argnames=("p", "tile_boxes", "interpret"))
def _l2p_pallas(br, bi, tr, ti, *, p: int, tile_boxes: int, interpret: bool):
    nbox, P = br.shape
    n_pad = tr.shape[1]
    TB = tile_boxes
    ntile = -(-nbox // TB)
    br, bi = pad_rows(br, ntile * TB), pad_rows(bi, ntile * TB)
    tr, ti = pad_rows(tr, ntile * TB), pad_rows(ti, ntile * TB)

    def row(b):
        return (b, 0)

    dt = tr.dtype
    outr, outi = pl.pallas_call(
        _make_kernel(p),
        grid=(ntile,),
        in_specs=[
            pl.BlockSpec((TB, P), row),
            pl.BlockSpec((TB, P), row),
            pl.BlockSpec((TB, n_pad), row),
            pl.BlockSpec((TB, n_pad), row),
        ],
        out_specs=[
            pl.BlockSpec((TB, n_pad), row),
            pl.BlockSpec((TB, n_pad), row),
        ],
        out_shape=[jax.ShapeDtypeStruct((ntile * TB, n_pad), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(br, bi, tr, ti)
    return outr[:nbox], outi[:nbox]


def l2p_pallas(br, bi, tr, ti, *, p: int, tile_boxes: int = 8,
               interpret: bool | None = None):
    """br/bi: (nbox, P) local planes; tr/ti: (nbox, n_pad) pre-centered
    particle planes (z - z0). Returns (outr, outi): (nbox, n_pad).
    ``interpret=None`` auto-selects from the JAX platform."""
    return _l2p_pallas(br, bi, tr, ti, p=p, tile_boxes=tile_boxes,
                       interpret=resolve_interpret(interpret))

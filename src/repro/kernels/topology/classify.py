"""Pallas TPU kernel: leaf-level strong/weak/swapped-theta classification.

The leaf level holds 3/4 of all boxes, so its classification dominates
the connect phase. One grid step classifies a ``tile_boxes`` tile of
target boxes against their full (4S-wide) candidate row: the (1, nbox)
center/radius planes of the leaf level stay VMEM-resident across the
whole grid (a few KB — leaf counts are 4**L), candidate geometry is
gathered from them in-register, and the kernel emits the five *keyed*
arrays (strong, weak, p2p, p2l, m2p: kept entries carry the candidate
id, dropped entries INT32_MAX) that ``build_connectivity`` feeds to its
single batched compaction sort.

The elementwise predicates are the exact plane-form formulas of
``core.topology.connectivity._theta_masks`` / ``_swapped_masks`` — the
two paths must agree bit-for-bit, which the parity sweep in
tests/test_topology.py checks on every distribution.

NOTE on the in-kernel gather: candidate geometry is fetched with
``jnp.take`` from the resident planes. Interpret mode (CPU, how this
repo tests) executes it directly; on real TPUs Mosaic lowers last-dim
dynamic gathers on newer toolchains only — if a target toolchain
rejects it, stage per-slot rows through scalar prefetch like the P2P
kernel instead.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import compiler_params, pad_rows, resolve_interpret, round_up

_INT_MAX = np.int32(np.iinfo(np.int32).max)


def _make_kernel(theta: float, use_p2l_m2p: bool):
    def body(cand_ref, tbx_ref, tby_ref, tbr_ref, cxf_ref, cyf_ref, rf_ref,
             ks_ref, kw_ref, kp_ref, kl_ref, km_ref):
        cand = cand_ref[...]                      # (TB, Cp) int32, -1 invalid
        valid = cand >= 0
        dummy = cxf_ref.shape[1] - 1              # zeroed trailing plane slot
        idx = jnp.where(valid, cand, dummy)
        ccx = jnp.take(cxf_ref[0, :], idx)        # (TB, Cp) candidate geometry
        ccy = jnp.take(cyf_ref[0, :], idx)
        rc = jnp.take(rf_ref[0, :], idx)
        ccx = jnp.where(valid, ccx, 0.0)
        ccy = jnp.where(valid, ccy, 0.0)
        rc = jnp.where(valid, rc, 0.0)

        tbx = tbx_ref[...]                        # (TB, 1) target geometry
        tby = tby_ref[...]
        rb = tbr_ref[...]
        d = jnp.hypot(tbx - ccx, tby - ccy)
        big = jnp.maximum(rb, rc)
        small = jnp.minimum(rb, rc)
        wellsep = (big + theta * small) <= (theta * d)
        weak_m = valid & wellsep
        strong_m = valid & ~wellsep
        if use_p2l_m2p:
            swapped = (small + theta * big) <= (theta * d)
            p2l_m = strong_m & swapped & (rc > rb)
            m2p_m = strong_m & swapped & (rc < rb)
            p2p_m = strong_m & ~(p2l_m | m2p_m)
        else:
            p2p_m = strong_m
            p2l_m = m2p_m = jnp.zeros_like(strong_m)

        def key(mask):
            return jnp.where(mask, cand, _INT_MAX)

        ks_ref[...] = key(strong_m)
        kw_ref[...] = key(weak_m)
        kp_ref[...] = key(p2p_m)
        kl_ref[...] = key(p2l_m)
        km_ref[...] = key(m2p_m)

    return body


@functools.partial(jax.jit, static_argnames=("theta", "use_p2l_m2p",
                                             "tile_boxes", "interpret"))
def _classify_pallas(cand, tbx, tby, tbr, cxf, cyf, rf, *, theta: float,
                     use_p2l_m2p: bool, tile_boxes: int, interpret: bool):
    nb, C = cand.shape
    TB = tile_boxes
    ntile = -(-nb // TB)
    Cp = round_up(C, 128)
    cand = pad_rows(jnp.pad(cand, ((0, 0), (0, Cp - C)), constant_values=-1),
                    ntile * TB, -1)

    def col(a):
        return pad_rows(a.reshape(-1, 1), ntile * TB)

    def tgt_map(i):
        return (i, 0)

    def full_map(i):
        return (0, 0)

    outs = pl.pallas_call(
        _make_kernel(theta, use_p2l_m2p),
        grid=(ntile,),
        in_specs=[pl.BlockSpec((TB, Cp), tgt_map)]
        + [pl.BlockSpec((TB, 1), tgt_map)] * 3
        + [pl.BlockSpec((1, cxf.shape[1]), full_map)] * 3,
        out_specs=[pl.BlockSpec((TB, Cp), tgt_map)] * 5,
        out_shape=[jax.ShapeDtypeStruct((ntile * TB, Cp), jnp.int32)] * 5,
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(cand, col(tbx), col(tby), col(tbr), cxf, cyf, rf)
    return tuple(o[:nb, :C] for o in outs)


def leaf_classify_pallas(cand, valid, centers, radii, cfg,
                         interpret: bool | None = None):
    """Pallas twin of ``leaf_classify_reference`` (the
    ``leaf_classify_impl`` topology hook).

    ``cand``/``valid``: (4**L, 4S) candidates; ``centers``/``radii``: the
    leaf-level box geometry. Returns the five keyed (4**L, 4S) int32
    arrays. ``interpret=None`` auto-selects from the JAX platform.
    """
    rdt = cfg.real_dtype
    nb = centers.shape[0]
    nbp = round_up(nb + 1, 128)

    def plane(a):
        return jnp.pad(a.astype(rdt), (0, nbp - nb)).reshape(1, nbp)

    cxf, cyf = plane(jnp.real(centers)), plane(jnp.imag(centers))
    rf = plane(radii)
    tbx = jnp.real(centers).astype(rdt)
    tby = jnp.imag(centers).astype(rdt)
    tbr = radii.astype(rdt)
    cand = jnp.where(valid, cand, -1).astype(jnp.int32)
    return _classify_pallas(cand, tbx, tby, tbr, cxf, cyf, rf,
                            theta=cfg.theta, use_p2l_m2p=cfg.use_p2l_m2p,
                            tile_boxes=cfg.tile_boxes,
                            interpret=resolve_interpret(interpret))

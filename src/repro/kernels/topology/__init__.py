from .classify import leaf_classify_pallas

__all__ = ["leaf_classify_pallas"]

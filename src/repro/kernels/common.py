"""Shared helpers for the Pallas TPU kernels.

TPU has no native complex arithmetic in Pallas, so every kernel operates on
separate real/imag f32 (or f64 in interpret mode) planes. Particle data is
staged into *dense per-leaf-box* arrays of shape (nbox+1, n_pad): row `nbox`
is an all-zero dummy row that -1 (masked) interaction-list entries are
redirected to, so the kernels never branch on list validity — a zero-strength
source contributes exactly zero. ``n_pad`` is the max leaf population rounded
up to the 128-lane width.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def default_interpret() -> bool:
    """Pallas interpret mode: True off-TPU (this container is CPU-only)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Auto-select interpret mode from the JAX platform when unset.

    Every kernel entry point takes ``interpret=None`` by default and
    resolves it here: compiled on a real TPU, interpreted elsewhere — so
    no caller has to thread the flag explicitly.
    """
    return default_interpret() if interpret is None else bool(interpret)


def pad_rows(a: jax.Array, nrows: int, value=0):
    """Pad a (rows, ...) array with ``value`` rows up to ``nrows``."""
    extra = nrows - a.shape[0]
    if extra == 0:
        return a
    widths = ((0, extra),) + ((0, 0),) * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=value)


def staged_list_specs(lists: jax.Array, dummy: int, TB: int, SW: int,
                      width: int):
    """Tiled scalar-prefetch staging shared by the P2P and M2L kernels.

    Pads the (nbox, S) interaction list for a ``(ntile, S_pad // SW)``
    grid of ``TB``-target-box tiles — masked (-1) and padding entries
    redirected to the all-zero ``dummy`` row — and builds one
    ``(1, width)`` scalar-prefetch-indexed BlockSpec per staged source
    row: spec (w, tb) DMAs the row named by list entry
    ``[i*TB + tb, s*SW + w]`` at grid step (i, s).

    Returns ``(padded_lists, src_specs, ntile)``.
    """
    nbox, S = lists.shape
    ntile = -(-nbox // TB)
    S_pad = round_up(S, SW)
    lists = jnp.where(lists >= 0, lists, dummy)
    lists = pad_rows(lists, ntile * TB, dummy)
    lists = jnp.pad(lists, ((0, 0), (0, S_pad - S)), constant_values=dummy)

    def make_src_map(w, tb):
        def src_map(i, s, lref):
            return (lref[i * TB + tb, s * SW + w], 0)
        return src_map

    specs = [pl.BlockSpec((1, width), make_src_map(w, tb))
             for w in range(SW) for tb in range(TB)]
    return lists, specs, ntile


def compiler_params(**kwargs):
    """TPU compiler params across jax versions (CompilerParams was named
    TPUCompilerParams before jax 0.5)."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def planes(z: jax.Array):
    return jnp.real(z), jnp.imag(z)


def dense_leaf_arrays(z: jax.Array, q: jax.Array, idx: np.ndarray,
                      n_pad: int):
    """Gather rank-sorted particles into (nbox+1, n_pad) dense planes.

    Returns (zr, zi, qr, qi, tmask) where the trailing dummy row is zero and
    padded slots carry q = 0 (and are additionally masked out of *target*
    positions by ``tmask``).
    """
    nbox, n_max = idx.shape
    pad_cols = n_pad - n_max
    idxj = jnp.asarray(idx)
    valid = idxj >= 0
    safe = jnp.where(valid, idxj, 0)
    zr = jnp.where(valid, jnp.real(z)[safe], 0.0)
    zi = jnp.where(valid, jnp.imag(z)[safe], 0.0)
    qr = jnp.where(valid, jnp.real(q)[safe], 0.0)
    qi = jnp.where(valid, jnp.imag(q)[safe], 0.0)

    def pack(a):
        a = jnp.pad(a, ((0, 1), (0, pad_cols)))
        return a

    return pack(zr), pack(zi), pack(qr), pack(qi), jnp.pad(valid, ((0, 1), (0, pad_cols)))


def scatter_from_leaves(values: jax.Array, idx: np.ndarray, n: int):
    """Scatter (nbox, n_pad)->(n,) rank order; padded slots masked to rank 0."""
    nbox, n_max = idx.shape
    vals = values[:, :n_max].reshape(-1)
    flat_idx = jnp.asarray(idx).reshape(-1)
    ok = flat_idx >= 0
    out = jnp.zeros((n,), values.dtype)
    return out.at[jnp.where(ok, flat_idx, 0)].add(jnp.where(ok, vals, 0.0))

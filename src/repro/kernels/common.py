"""Shared helpers for the Pallas TPU kernels.

TPU has no native complex arithmetic in Pallas, so every kernel operates on
separate real/imag f32 (or f64 in interpret mode) planes. Particle data is
staged into *dense per-leaf-box* arrays of shape (nbox+1, n_pad): row `nbox`
is an all-zero dummy row that -1 (masked) interaction-list entries are
redirected to, so the kernels never branch on list validity — a zero-strength
source contributes exactly zero. ``n_pad`` is the max leaf population rounded
up to the 128-lane width.

Every kernel grid is *batch-major* (DESIGN.md §2): operands carry a
leading problem axis B, the grid is ``(B, ntile, steps)`` with
``program_id(0)`` selecting the problem, and the interaction lists ride
in SMEM as one (B, nbox, S) scalar-prefetch operand whose BlockSpec
index maps take the batch coordinate first. B problems therefore
lengthen the grid without touching the per-step VMEM working set —
single-problem callers run the same kernels at B = 1, and
``jax.vmap`` of the per-problem wrappers lowers onto the batched grid
through their custom batching rules (see the ``*_op`` factories in each
kernel module).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def default_interpret() -> bool:
    """Pallas interpret mode: True off-TPU (this container is CPU-only)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Auto-select interpret mode from the JAX platform when unset.

    Every kernel entry point takes ``interpret=None`` by default and
    resolves it here: compiled on a real TPU, interpreted elsewhere — so
    no caller has to thread the flag explicitly.
    """
    return default_interpret() if interpret is None else bool(interpret)


def pad_rows(a: jax.Array, nrows: int, value=0):
    """Pad a (rows, ...) array with ``value`` rows up to ``nrows``."""
    extra = nrows - a.shape[0]
    if extra == 0:
        return a
    widths = ((0, extra),) + ((0, 0),) * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=value)


def pad_boxes(a: jax.Array, nrows: int, value=0):
    """Pad the box axis (axis -2) of a batch-major array up to ``nrows``."""
    extra = nrows - a.shape[-2]
    if extra == 0:
        return a
    widths = ((0, 0),) * (a.ndim - 2) + ((0, extra), (0, 0))
    return jnp.pad(a, widths, constant_values=value)


def broadcast_unbatched(args, in_batched, axis_size: int):
    """Broadcast the unbatched operands of a custom-vmap rule to the full
    (B, ...) batch-major shape the kernels expect. Operands already
    carrying the mapped axis (moved to front by ``jax.custom_batching``)
    pass through untouched."""
    return [a if b else jnp.broadcast_to(a[None], (axis_size,) + a.shape)
            for a, b in zip(args, in_batched)]


def make_batched_op(batched_call):
    """Per-problem view of a batch-major kernel entry, with the custom
    batching rule that makes it batch-native.

    ``batched_call(*args)`` must take operands with a leading problem
    axis B and return a tuple of (B, ...) outputs. The returned op takes
    the same operands *without* the batch axis; calling it runs the
    kernel at B = 1, and ``jax.vmap`` of it lowers onto the batch-major
    grid directly — one launch for the whole batch — broadcasting any
    unbatched operands first. Kernels whose operand list varies by
    static config (m2l's log planes, the fused evaluation's m2p region)
    wrap their own rule instead.
    """
    @jax.custom_batching.custom_vmap
    def op(*args):
        outs = batched_call(*(a[None] for a in args))
        return tuple(o[0] for o in outs)

    @op.def_vmap
    def _rule(axis_size, in_batched, *args):
        outs = batched_call(*broadcast_unbatched(args, in_batched,
                                                 axis_size))
        return tuple(outs), tuple(True for _ in outs)

    return op


def prefetch_row_specs(TB: int, SW: int, width: int):
    """One ``(None, 1, width)`` scalar-prefetch-indexed BlockSpec per
    staged source row on the batch-major grid: spec (w, tb) DMAs the row
    of problem ``b`` named by list entry ``[b, i*TB + tb, s*SW + w]`` at
    grid step (b, i, s). The list itself is the first scalar-prefetch
    operand (``lref``, shape (B, ntile*TB, S_pad)); the leading ``None``
    block dim squeezes the batch axis so the kernel body sees the same
    (1, width) rows as a single-problem launch."""

    def make_src_map(w, tb):
        def src_map(b, i, s, lref):
            return (b, lref[b, i * TB + tb, s * SW + w], 0)
        return src_map

    return [pl.BlockSpec((None, 1, width), make_src_map(w, tb))
            for w in range(SW) for tb in range(TB)]


def staged_list_specs(lists: jax.Array, dummy: int, TB: int, SW: int,
                      width: int):
    """Tiled scalar-prefetch staging shared by the P2P and M2L kernels.

    Pads the (B, nbox, S) interaction lists for a ``(B, ntile,
    S_pad // SW)`` batch-major grid of ``TB``-target-box tiles — masked
    (-1) and padding entries redirected to the all-zero ``dummy`` row —
    and builds one ``(None, 1, width)`` scalar-prefetch-indexed
    BlockSpec per staged source row (see ``prefetch_row_specs``).

    Returns ``(padded_lists, src_specs, ntile)``.
    """
    _, nbox, S = lists.shape
    ntile = -(-nbox // TB)
    S_pad = round_up(S, SW)
    lists = jnp.where(lists >= 0, lists, dummy)
    lists = jnp.pad(lists, ((0, 0), (0, ntile * TB - nbox), (0, S_pad - S)),
                    constant_values=dummy)
    return lists, prefetch_row_specs(TB, SW, width), ntile


def staged_multilist(lists_seq, dummy: int, TB: int, SW: int):
    """Concatenate several interaction lists along the slot axis for one
    fused batch-major grid: each (B, nbox, S_k) region is
    dummy-redirected and padded to a multiple of ``SW`` so it owns a
    whole number of grid steps; the combined list is box-padded for the
    TB-tile grid.

    Returns ``(combined, ntile, region_steps)`` where ``region_steps[k]``
    is the number of SW-wide grid steps of region k — the kernel branches
    on the step axis ``pl.program_id(2)`` against the running step
    offsets to know which interaction type a step carries.
    """
    nbox = lists_seq[0].shape[-2]
    ntile = -(-nbox // TB)
    regions, steps = [], []
    for lists in lists_seq:
        S = lists.shape[-1]
        S_pad = round_up(S, SW)
        l = jnp.where(lists >= 0, lists, dummy)
        l = jnp.pad(l, ((0, 0), (0, 0), (0, S_pad - S)),
                    constant_values=dummy)
        regions.append(l)
        steps.append(S_pad // SW)
    combined = pad_boxes(jnp.concatenate(regions, axis=-1), ntile * TB,
                         dummy)
    return combined, ntile, steps


def compiler_params(**kwargs):
    """TPU compiler params across jax versions (CompilerParams was named
    TPUCompilerParams before jax 0.5)."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def planes(z: jax.Array):
    return jnp.real(z), jnp.imag(z)


def dense_leaf_arrays(z: jax.Array, q: jax.Array, idx: np.ndarray,
                      n_pad: int):
    """Gather rank-sorted particles into (nbox+1, n_pad) dense planes.

    Returns (zr, zi, qr, qi, tmask) where the trailing dummy row is zero and
    padded slots carry q = 0 (and are additionally masked out of *target*
    positions by ``tmask``).
    """
    nbox, n_max = idx.shape
    pad_cols = n_pad - n_max
    idxj = jnp.asarray(idx)
    valid = idxj >= 0
    safe = jnp.where(valid, idxj, 0)
    zr = jnp.where(valid, jnp.real(z)[safe], 0.0)
    zi = jnp.where(valid, jnp.imag(z)[safe], 0.0)
    qr = jnp.where(valid, jnp.real(q)[safe], 0.0)
    qi = jnp.where(valid, jnp.imag(q)[safe], 0.0)

    def pack(a):
        a = jnp.pad(a, ((0, 1), (0, pad_cols)))
        return a

    return pack(zr), pack(zi), pack(qr), pack(qi), jnp.pad(valid, ((0, 1), (0, pad_cols)))


def pairwise_tile(kernel: str, tzr, tzi, trk, szr, szi, qr, qi, srk):
    """One staged P2P source tile against the resident targets.

    All inputs (TB, n_pad); returns the (TB, n_pad) (real, imag)
    contribution to accumulate. Shared by the standalone P2P kernel and
    the fused evaluation megakernel so the kernel math (including the
    rank-based self-exclusion) has exactly one definition.
    """
    dx = szr[:, None, :] - tzr[:, :, None]   # (TB, n_t, n_s): z_src - z_tgt
    dy = szi[:, None, :] - tzi[:, :, None]
    qr, qi = qr[:, None, :], qi[:, None, :]
    d2 = dx * dx + dy * dy
    # self-interaction excluded by particle identity (global rank), never
    # by position: distinct coincident particles interact (singular
    # contribution — the correct sum_{j != i} semantics).
    ok = (srk[:, None, :] >= 0) & (srk[:, None, :] != trk[:, :, None])
    if kernel == "harmonic":
        # q / (dx + i dy) = q * (dx - i dy) / |d|^2
        inv = jnp.where(ok, 1.0 / d2, 0.0)
        return (((qr * dx + qi * dy) * inv).sum(axis=-1),
                ((qi * dx - qr * dy) * inv).sum(axis=-1))
    # q * log(z_t - z_s) = q * (log|d| + i*arg(-dx, -dy))
    lr = jnp.where(ok, 0.5 * jnp.log(d2), 0.0)
    li = jnp.where(ok, jnp.arctan2(-dy, -dx), 0.0)
    return ((qr * lr - qi * li).sum(axis=-1),
            (qr * li + qi * lr).sum(axis=-1))


def l2p_horner(p: int, br_ref, bi_ref, tr, ti):
    """Local-expansion Horner at pre-centered particles.

    br_ref/bi_ref: (TB, P) coefficient block (ref or array; read as
    per-row (TB, 1) columns at static lane indices); tr/ti: (TB, n_pad).
    Returns the (TB, n_pad) (real, imag) potential. Shared by the L2P
    kernel and the fused evaluation megakernel's output seed.
    """
    accr = jnp.zeros_like(tr) + br_ref[:, p:p + 1]
    acci = jnp.zeros_like(ti) + bi_ref[:, p:p + 1]
    for j in range(p - 1, -1, -1):
        nr = accr * tr - acci * ti + br_ref[:, j:j + 1]
        ni = accr * ti + acci * tr + bi_ref[:, j:j + 1]
        accr, acci = nr, ni
    return accr, acci


def dense_rank_planes(idx: np.ndarray, n_pad: int) -> jax.Array:
    """(nbox+1, n_pad) int32 global particle ranks per dense leaf slot.

    Padded slots and the trailing dummy row carry -1, so rank equality
    against a valid target rank is never spuriously true — this is the
    plane the kernels compare to exclude self-interaction *by particle
    identity* (rank i == rank j), not by position coincidence, so
    distinct particles at duplicated positions still interact (their
    mutual contribution is the kernel singularity, by definition of
    phi_i = sum_{j != i} G(z_i, x_j)).
    """
    nbox, n_max = idx.shape
    return jnp.pad(jnp.asarray(idx, jnp.int32),
                   ((0, 1), (0, n_pad - n_max)), constant_values=-1)


def scatter_from_leaves(values: jax.Array, idx: np.ndarray, n: int):
    """Scatter (nbox, n_pad)->(n,) rank order; padded slots masked to rank 0."""
    nbox, n_max = idx.shape
    vals = values[:, :n_max].reshape(-1)
    flat_idx = jnp.asarray(idx).reshape(-1)
    ok = flat_idx >= 0
    out = jnp.zeros((n,), values.dtype)
    return out.at[jnp.where(ok, flat_idx, 0)].add(jnp.where(ok, vals, 0.0))

"""Pallas TPU kernels for the FMM hot spots (paper Table 5.1):

  eval/   FUSED evaluation phase (L2P + M2P + P2P in one launch, ~56%
          of GPU runtime) + the downward P2L kernel
  p2p/    near-field direct evaluation (43% of GPU runtime)
  m2l/    multipole-to-local level sweep (11%)
  l2p/    local evaluation (2%)
  nbody/  direct summation baseline (Figs 5.5/5.6)

Each subpackage ships <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd wrapper with the FMM-pipeline contract) and ref.py
(pure-jnp oracle). Validated with interpret=True on CPU; TPU is the target.
Every kernel grid is *batch-major*: the per-problem ``*_pallas`` entry
points carry custom batching rules that lower ``jax.vmap`` onto a
(B, ...) grid — B problems per launch, one launch per phase — and the
``*_pallas_batched`` twins take the batch-major operands directly.
The topological phase's sort/scan/compaction primitives stay on XLA:TPU
(DESIGN.md §2), but its leaf-level classification — 3/4 of all boxes —
ships as a kernel:

  topology/  leaf-level strong/weak/swapped-theta classification
             (the ``Backend.leaf_classify`` topology hook)

Consumers should not import these wrappers directly for pipeline use:
the backend registry in ``repro.solver.backends`` bundles them as the
"pallas" backend (vs the "reference" jnp sweeps) and ``FmmSolver``
dispatches each phase through it — swap implementations per phase by
backend name, or register new ones with ``register_backend``.
"""
from . import common
from .eval import eval_fused_apply, eval_fused_pallas, \
    eval_fused_pallas_batched, m2p_ref, p2l_apply, p2l_pallas, \
    p2l_pallas_batched
from .p2p import p2p_apply, p2p_pallas, p2p_pallas_batched, p2p_ref
from .m2l import m2l_fused_apply, m2l_level_apply, m2l_pallas, \
    m2l_pallas_batched, m2l_ref
from .l2p import l2p_apply, l2p_pallas, l2p_pallas_batched, l2p_ref
from .nbody import nbody_direct, nbody_pallas, nbody_ref
from .topology import leaf_classify_pallas

__all__ = [
    "common",
    "eval_fused_apply", "eval_fused_pallas", "eval_fused_pallas_batched",
    "m2p_ref", "p2l_apply", "p2l_pallas", "p2l_pallas_batched",
    "p2p_apply", "p2p_pallas", "p2p_pallas_batched", "p2p_ref",
    "m2l_fused_apply", "m2l_level_apply", "m2l_pallas",
    "m2l_pallas_batched", "m2l_ref",
    "l2p_apply", "l2p_pallas", "l2p_pallas_batched", "l2p_ref",
    "nbody_direct", "nbody_pallas", "nbody_ref",
    "leaf_classify_pallas",
]

"""Jit'd wrapper wiring the P2P Pallas kernel into the FMM pipeline."""
from __future__ import annotations

import numpy as np

from ...core.config import FmmConfig
from ..common import (dense_leaf_arrays, dense_rank_planes, round_up,
                      scatter_from_leaves)
from .p2p import p2p_pallas


def p2p_apply(tree, conn, cfg: FmmConfig, idx: np.ndarray,
              interpret: bool | None = None):
    """Drop-in ``p2p_impl`` for ``repro.core.fmm.fmm_evaluate``.

    Returns (n,) complex potential contribution in rank order.
    """
    idx = np.asarray(idx)
    n_pad = round_up(idx.shape[1], 128)
    zr, zi, qr, qi, _ = dense_leaf_arrays(tree.z, tree.q, idx, n_pad)
    rk = dense_rank_planes(idx, n_pad)
    outr, outi = p2p_pallas(conn.p2p, zr[:-1], zi[:-1], rk[:-1],
                            zr, zi, qr, qi, rk,
                            kernel=cfg.kernel, tile_boxes=cfg.tile_boxes,
                            stage_width=cfg.stage_width, interpret=interpret)
    return scatter_from_leaves(outr + 1j * outi, idx, cfg.n)

"""Jit'd wrapper wiring the P2P Pallas kernel into the FMM pipeline."""
from __future__ import annotations

import numpy as np

from ...core.config import FmmConfig
from ..common import (default_interpret, dense_leaf_arrays, round_up,
                      scatter_from_leaves)
from .p2p import p2p_pallas


def p2p_apply(tree, conn, cfg: FmmConfig, idx: np.ndarray,
              interpret: bool | None = None):
    """Drop-in ``p2p_impl`` for ``repro.core.fmm.fmm_evaluate``.

    Returns (n,) complex potential contribution in rank order.
    """
    if cfg.kernel != "harmonic":
        raise NotImplementedError("Pallas P2P implements the harmonic kernel")
    if interpret is None:
        interpret = default_interpret()
    idx = np.asarray(idx)
    n_pad = round_up(idx.shape[1], 128)
    zr, zi, qr, qi, _ = dense_leaf_arrays(tree.z, tree.q, idx, n_pad)
    outr, outi = p2p_pallas(conn.p2p, zr[:-1], zi[:-1], zr, zi, qr, qi,
                            interpret=interpret)
    return scatter_from_leaves(outr + 1j * outi, idx, cfg.n)

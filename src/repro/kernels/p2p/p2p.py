"""Pallas TPU kernel: near-field direct evaluation over leaf P2P lists.

This is the paper's Algorithm 3.7 (43% of GPU runtime, Table 5.1) mapped to
the TPU memory hierarchy. The CUDA version stages source positions for one
interaction box at a time into 48 kB shared memory with one block per target
box; here each grid step (b, s) stages one (1, n_pad) source-box tile from
HBM into VMEM via a *scalar-prefetch indexed BlockSpec* — the interaction
list itself rides in SMEM and selects which block of the dense leaf array to
DMA, so the hot loop contains no gather at all (the static leaf layout of
the asymmetric tree is what makes this possible). The (n_pad, n_pad)
pairwise tile lives entirely in VREGs/VMEM.

Grid: (nbox, strong_cap); output revisited across s -> accumulate in place
(dimension_semantics: "arbitrary" on s).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import compiler_params


def _p2p_kernel(lists_ref, tzr, tzi, szr, szi, sqr, sqi, outr, outi):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        outr[...] = jnp.zeros_like(outr)
        outi[...] = jnp.zeros_like(outi)

    # (n_t, n_s) pairwise tile: diff = z_src - z_tgt
    dx = szr[0][None, :] - tzr[0][:, None]
    dy = szi[0][None, :] - tzi[0][:, None]
    denom = dx * dx + dy * dy
    ok = denom > 0.0                       # excludes coincident + zero pads
    inv = jnp.where(ok, 1.0 / jnp.where(ok, denom, 1.0), 0.0)
    qr = sqr[0][None, :]
    qi = sqi[0][None, :]
    # q / (dx + i dy) = q * (dx - i dy) / |d|^2
    outr[...] += ((qr * dx + qi * dy) * inv).sum(axis=1)[None, :]
    outi[...] += ((qi * dx - qr * dy) * inv).sum(axis=1)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def p2p_pallas(lists: jax.Array, tzr, tzi, szr, szi, sqr, sqi,
               *, interpret: bool = True):
    """lists: (nbox, S) int32 (-1 masked). Dense planes: (nbox[+1], n_pad).

    Returns (outr, outi): (nbox, n_pad) potential at the dense leaf slots.
    """
    nbox, S = lists.shape
    n_pad = tzr.shape[1]
    dummy = szr.shape[0] - 1  # index of the all-zero row
    lists = jnp.where(lists >= 0, lists, dummy)

    def tgt_map(b, s, lref):
        return (b, 0)

    def src_map(b, s, lref):
        return (lref[b, s], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbox, S),
        in_specs=[
            pl.BlockSpec((1, n_pad), tgt_map),
            pl.BlockSpec((1, n_pad), tgt_map),
            pl.BlockSpec((1, n_pad), src_map),
            pl.BlockSpec((1, n_pad), src_map),
            pl.BlockSpec((1, n_pad), src_map),
            pl.BlockSpec((1, n_pad), src_map),
        ],
        out_specs=[
            pl.BlockSpec((1, n_pad), tgt_map),
            pl.BlockSpec((1, n_pad), tgt_map),
        ],
    )
    dt = tzr.dtype
    return pl.pallas_call(
        _p2p_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((nbox, n_pad), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lists, tzr, tzi, szr, szi, sqr, sqi)

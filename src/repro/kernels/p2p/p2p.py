"""Pallas TPU kernel: near-field direct evaluation over leaf P2P lists.

This is the paper's Algorithm 3.7 (43% of GPU runtime, Table 5.1) mapped to
the TPU memory hierarchy. The CUDA version stages source positions for one
interaction box at a time into 48 kB shared memory with one block per target
box; here a grid step owns a *tile* of ``tile_boxes`` target boxes
(DESIGN.md §2): the (TB, n_pad) target planes and the revisited (TB, n_pad)
output block stay resident in VMEM across the whole interaction list, and
each step stages ``tile_boxes * stage_width`` source-box rows from HBM via
*scalar-prefetch indexed BlockSpecs* — the interaction list itself rides in
SMEM and selects which block of the dense leaf array to DMA, so the hot
loop contains no gather at all (the static leaf layout of the asymmetric
tree is what makes this possible). Pallas double-buffers the streaming
source tiles, overlapping the next DMA with the (TB, n_pad, n_pad)
pairwise tile evaluated in VREGs.

Grid: (ceil(nbox/TB), ceil(S/SW)); output revisited across the list axis
-> accumulate in place (dimension_semantics: "arbitrary" on it).

Both G-kernels: "harmonic" q/(x - z) and "log" q*log(z - x).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import (compiler_params, pad_rows, pairwise_tile,
                      resolve_interpret, staged_list_specs)


def _make_kernel(kernel: str, TB: int, SW: int):
    def body(lists_ref, tzr_ref, tzi_ref, trk_ref, *rest):
        n = TB * SW
        szr_refs, szi_refs = rest[:n], rest[n:2 * n]
        sqr_refs, sqi_refs = rest[2 * n:3 * n], rest[3 * n:4 * n]
        srk_refs = rest[4 * n:5 * n]
        outr, outi = rest[5 * n], rest[5 * n + 1]
        s = pl.program_id(1)

        @pl.when(s == 0)
        def _init():
            outr[...] = jnp.zeros_like(outr)
            outi[...] = jnp.zeros_like(outi)

        tzr = tzr_ref[...]                     # (TB, n_pad) resident targets
        tzi = tzi_ref[...]
        trk = trk_ref[...]                     # (TB, n_pad) global ranks
        for w in range(SW):
            o = w * TB

            def tile(refs):
                return jnp.concatenate([r[...] for r in refs[o:o + TB]],
                                       axis=0)

            dr, di = pairwise_tile(kernel, tzr, tzi, trk,
                                   tile(szr_refs), tile(szi_refs),
                                   tile(sqr_refs), tile(sqi_refs),
                                   tile(srk_refs))
            outr[...] += dr
            outi[...] += di

    return body


@functools.partial(jax.jit, static_argnames=("kernel", "tile_boxes",
                                             "stage_width", "interpret"))
def _p2p_pallas(lists: jax.Array, tzr, tzi, trk, szr, szi, sqr, sqi, srk, *,
                kernel: str, tile_boxes: int, stage_width: int,
                interpret: bool):
    nbox = lists.shape[0]
    n_pad = tzr.shape[1]
    TB, SW = tile_boxes, stage_width
    dummy = szr.shape[0] - 1  # index of the all-zero row

    lists, src_specs, ntile = staged_list_specs(lists, dummy, TB, SW, n_pad)
    tzr = pad_rows(tzr, ntile * TB)
    tzi = pad_rows(tzi, ntile * TB)
    trk = pad_rows(trk, ntile * TB, -1)

    def tgt_map(i, s, lref):
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ntile, lists.shape[1] // SW),
        in_specs=[pl.BlockSpec((TB, n_pad), tgt_map),
                  pl.BlockSpec((TB, n_pad), tgt_map),
                  pl.BlockSpec((TB, n_pad), tgt_map)] + src_specs * 5,
        out_specs=[
            pl.BlockSpec((TB, n_pad), tgt_map),
            pl.BlockSpec((TB, n_pad), tgt_map),
        ],
    )
    dt = tzr.dtype
    n = TB * SW
    outr, outi = pl.pallas_call(
        _make_kernel(kernel, TB, SW),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((ntile * TB, n_pad), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lists, tzr, tzi, trk, *([szr] * n), *([szi] * n), *([sqr] * n),
      *([sqi] * n), *([srk] * n))
    return outr[:nbox], outi[:nbox]


def p2p_pallas(lists: jax.Array, tzr, tzi, trk, szr, szi, sqr, sqi, srk, *,
               kernel: str = "harmonic", tile_boxes: int = 8,
               stage_width: int = 1, interpret: bool | None = None):
    """lists: (nbox, S) int32 (-1 masked). Dense planes: (nbox[+1], n_pad);
    trk/srk: int32 global-rank planes (-1 in padded slots / dummy row) —
    self-interaction is excluded where source rank == target rank.

    Returns (outr, outi): (nbox, n_pad) potential at the dense leaf slots.
    ``interpret=None`` auto-selects from the JAX platform (compiled on TPU).
    """
    return _p2p_pallas(lists, tzr, tzi, trk, szr, szi, sqr, sqi, srk,
                       kernel=kernel, tile_boxes=tile_boxes,
                       stage_width=stage_width,
                       interpret=resolve_interpret(interpret))

"""Pallas TPU kernel: near-field direct evaluation over leaf P2P lists.

This is the paper's Algorithm 3.7 (43% of GPU runtime, Table 5.1) mapped to
the TPU memory hierarchy. The CUDA version stages source positions for one
interaction box at a time into 48 kB shared memory with one block per target
box; here a grid step owns a *tile* of ``tile_boxes`` target boxes
(DESIGN.md §2): the (TB, n_pad) target planes and the revisited (TB, n_pad)
output block stay resident in VMEM across the whole interaction list, and
each step stages ``tile_boxes * stage_width`` source-box rows from HBM via
*scalar-prefetch indexed BlockSpecs* — the interaction list itself rides in
SMEM and selects which block of the dense leaf array to DMA, so the hot
loop contains no gather at all (the static leaf layout of the asymmetric
tree is what makes this possible). Pallas double-buffers the streaming
source tiles, overlapping the next DMA with the (TB, n_pad, n_pad)
pairwise tile evaluated in VREGs.

Grid: batch-major (B, ceil(nbox/TB), ceil(S/SW)); ``program_id(0)``
selects the problem, the output is revisited across the list axis ->
accumulate in place ("arbitrary" on it). B problems lengthen the grid
without touching the per-step VMEM working set; ``jax.vmap`` of
``p2p_pallas`` lowers onto this grid via the op's custom batching rule.

Both G-kernels: "harmonic" q/(x - z) and "log" q*log(z - x).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import (compiler_params, make_batched_op, pad_boxes,
                      pairwise_tile, resolve_interpret, staged_list_specs)


def _make_kernel(kernel: str, TB: int, SW: int):
    def body(lists_ref, tzr_ref, tzi_ref, trk_ref, *rest):
        n = TB * SW
        szr_refs, szi_refs = rest[:n], rest[n:2 * n]
        sqr_refs, sqi_refs = rest[2 * n:3 * n], rest[3 * n:4 * n]
        srk_refs = rest[4 * n:5 * n]
        outr, outi = rest[5 * n], rest[5 * n + 1]
        s = pl.program_id(2)

        @pl.when(s == 0)
        def _init():
            outr[...] = jnp.zeros_like(outr)
            outi[...] = jnp.zeros_like(outi)

        tzr = tzr_ref[...]                     # (TB, n_pad) resident targets
        tzi = tzi_ref[...]
        trk = trk_ref[...]                     # (TB, n_pad) global ranks
        for w in range(SW):
            o = w * TB

            def tile(refs):
                return jnp.concatenate([r[...] for r in refs[o:o + TB]],
                                       axis=0)

            dr, di = pairwise_tile(kernel, tzr, tzi, trk,
                                   tile(szr_refs), tile(szi_refs),
                                   tile(sqr_refs), tile(sqi_refs),
                                   tile(srk_refs))
            outr[...] += dr
            outi[...] += di

    return body


@functools.partial(jax.jit, static_argnames=("kernel", "tile_boxes",
                                             "stage_width", "interpret"))
def _p2p_pallas(lists: jax.Array, tzr, tzi, trk, szr, szi, sqr, sqi, srk, *,
                kernel: str, tile_boxes: int, stage_width: int,
                interpret: bool):
    """Batch-major core: lists (B, nbox, S), planes (B, nbox[+1], n_pad)."""
    B, nbox, _ = lists.shape
    n_pad = tzr.shape[-1]
    TB, SW = tile_boxes, stage_width
    dummy = szr.shape[-2] - 1  # index of the all-zero row

    lists, src_specs, ntile = staged_list_specs(lists, dummy, TB, SW, n_pad)
    tzr = pad_boxes(tzr, ntile * TB)
    tzi = pad_boxes(tzi, ntile * TB)
    trk = pad_boxes(trk, ntile * TB, -1)

    def tgt_map(b, i, s, lref):
        return (b, i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, ntile, lists.shape[-1] // SW),
        in_specs=[pl.BlockSpec((None, TB, n_pad), tgt_map),
                  pl.BlockSpec((None, TB, n_pad), tgt_map),
                  pl.BlockSpec((None, TB, n_pad), tgt_map)] + src_specs * 5,
        out_specs=[
            pl.BlockSpec((None, TB, n_pad), tgt_map),
            pl.BlockSpec((None, TB, n_pad), tgt_map),
        ],
    )
    dt = tzr.dtype
    n = TB * SW
    outr, outi = pl.pallas_call(
        _make_kernel(kernel, TB, SW),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, ntile * TB, n_pad), dt)] * 2,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lists, tzr, tzi, trk, *([szr] * n), *([szi] * n), *([sqr] * n),
      *([sqi] * n), *([srk] * n))
    return outr[:, :nbox], outi[:, :nbox]


@functools.lru_cache(maxsize=None)
def _p2p_op(kernel: str, tile_boxes: int, stage_width: int, interpret: bool):
    """Per-problem P2P op whose custom batching rule lowers ``jax.vmap``
    onto the batch-major kernel grid (one launch for B problems)."""
    return make_batched_op(functools.partial(
        _p2p_pallas, kernel=kernel, tile_boxes=tile_boxes,
        stage_width=stage_width, interpret=interpret))


def p2p_pallas(lists: jax.Array, tzr, tzi, trk, szr, szi, sqr, sqi, srk, *,
               kernel: str = "harmonic", tile_boxes: int = 8,
               stage_width: int = 1, interpret: bool | None = None):
    """lists: (nbox, S) int32 (-1 masked). Dense planes: (nbox[+1], n_pad);
    trk/srk: int32 global-rank planes (-1 in padded slots / dummy row) —
    self-interaction is excluded where source rank == target rank.

    Returns (outr, outi): (nbox, n_pad) potential at the dense leaf slots.
    ``interpret=None`` auto-selects from the JAX platform (compiled on
    TPU). Batch-native: under ``jax.vmap``, B problems compile to ONE
    batch-major launch (see ``p2p_pallas_batched``).
    """
    op = _p2p_op(kernel, tile_boxes, stage_width,
                 resolve_interpret(interpret))
    return op(lists, tzr, tzi, trk, szr, szi, sqr, sqi, srk)


def p2p_pallas_batched(lists: jax.Array, tzr, tzi, trk, szr, szi, sqr, sqi,
                       srk, *, kernel: str = "harmonic", tile_boxes: int = 8,
                       stage_width: int = 1, interpret: bool | None = None):
    """Batch-major entry: all operands carry a leading problem axis B;
    one (B, ntile, steps) launch returns (B, nbox, n_pad) planes."""
    return _p2p_pallas(lists, tzr, tzi, trk, szr, szi, sqr, sqi, srk,
                       kernel=kernel, tile_boxes=tile_boxes,
                       stage_width=stage_width,
                       interpret=resolve_interpret(interpret))

"""Pure-jnp oracle for the P2P kernel (both G-kernels, dense leaf layout)."""
from __future__ import annotations

import jax.numpy as jnp


def p2p_ref(lists, tzr, tzi, trk, szr, szi, sqr, sqi, srk,
            kernel: str = "harmonic"):
    """Same contract as p2p_pallas; returns (outr, outi) of (nbox, n_pad).

    Self-interaction is excluded by global rank identity (trk/srk planes,
    -1 in padded slots), not by position coincidence: distinct particles
    at duplicated positions contribute their (singular) mutual term.
    """
    nbox, S = lists.shape
    dummy = szr.shape[0] - 1
    lists = jnp.where(lists >= 0, lists, dummy)
    tz = tzr + 1j * tzi                      # (nbox, n_pad)
    sz = (szr + 1j * szi)[lists]             # (nbox, S, n_pad)
    sq = (sqr + 1j * sqi)[lists]
    srkL = srk[lists]                        # (nbox, S, n_pad)
    diff = sz[:, None, :, :] - tz[:, :, None, None]   # (nbox, n_t, S, n_s)
    ok = ((srkL[:, None, :, :] >= 0)
          & (srkL[:, None, :, :] != trk[:, :, None, None]))
    if kernel == "harmonic":
        c = jnp.where(ok, sq[:, None, :, :], 0.0) / jnp.where(ok, diff, 1.0)
    else:
        c = jnp.where(ok, sq[:, None, :, :]
                      * jnp.log(jnp.where(ok, -diff, 1.0)), 0.0)
    phi = c.sum(axis=(2, 3))
    return jnp.real(phi), jnp.imag(phi)

from .ops import p2p_apply
from .p2p import p2p_pallas, p2p_pallas_batched
from .ref import p2p_ref

__all__ = ["p2p_apply", "p2p_pallas", "p2p_pallas_batched", "p2p_ref"]

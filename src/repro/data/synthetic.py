"""Deterministic synthetic data.

Streams are *stateless*: batch contents are a pure function of
(seed, step), so any worker can regenerate any batch after a
restart/re-shard — no data-loader state in checkpoints, which is the
fault-tolerance-friendly design for 1000+ nodes (exercised by the
``Prefetcher``/runtime tests).

``particles`` reproduces the paper's three source distributions
(Fig. 5.8): uniform in the unit square, N(0, 1/100) and the 'layer'
distribution, all rejected to fit the unit square exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0


def lm_batch(dc: DataConfig, step: int):
    """Synthetic token batch, deterministic in (seed, step); used by the
    data-pipeline/prefetcher tests."""
    rng = np.random.default_rng(np.random.PCG64((dc.seed, step)))
    useful_vocab = min(dc.vocab, 1024)
    a = rng.integers(0, useful_vocab, (dc.batch, 1))
    b = rng.integers(1, 17, (dc.batch, 1))
    t = np.arange(dc.seq + 1)[None, :]
    toks = (a + b * t) % useful_vocab
    return {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }


# ---------------------------------------------------------------------------
# particle distributions (paper Fig. 5.8)
# ---------------------------------------------------------------------------

def particles(dist: str, n: int, seed: int = 0):
    """Complex positions in the unit square + unit-strength charges."""
    rng = np.random.default_rng(seed)

    def rejected(gen):
        out = np.empty(0, np.complex128)
        while out.size < n:
            z = gen(2 * (n - out.size) + 16)
            ok = (z.real >= 0) & (z.real <= 1) & (z.imag >= 0) & (z.imag <= 1)
            out = np.concatenate([out, z[ok]])
        return out[:n]

    if dist == "uniform":
        z = rng.uniform(0, 1, n) + 1j * rng.uniform(0, 1, n)
    elif dist == "normal":
        z = rejected(lambda m: (0.5 + rng.normal(0, 0.1, m))
                     + 1j * (0.5 + rng.normal(0, 0.1, m)))
    elif dist == "layer":
        z = rejected(lambda m: rng.uniform(0, 1, m)
                     + 1j * (0.5 + rng.normal(0, 0.1, m)))
    else:
        raise ValueError(dist)
    q = rng.normal(size=n)
    return jnp.asarray(z), jnp.asarray(q + 0j)


def ragged_requests(num: int, *, seed: int = 0, median_n: int = 256,
                    sigma: float = 0.8, n_min: int = 4,
                    n_max: int | None = None, poison_rate: float = 0.0,
                    dist: str = "uniform"):
    """Synthetic ragged serving workload: ``num`` requests whose sizes
    follow a log-normal distribution (the classic heavy-tailed traffic
    shape), with a configurable fraction of *poison* requests.

    Yields ``(n, z, q, kind)`` tuples, deterministic per ``(seed, i)``
    (stateless, like every stream in this module — any consumer can
    regenerate any request). ``kind`` is ``"ok"`` or the poison flavor:

      "nan-q"     one charge is NaN (non-finite input)
      "inf-z"     one position is Inf
      "real-z"    positions handed over as a real array (dtype confusion)
      "empty"     zero-length arrays

    Shared by the serving soak (``repro.testing.serve_faults``), the
    serving benchmark (``benchmarks/serving.py``) and the serve tests so
    all three exercise the *same* traffic distribution.
    """
    if not 0.0 <= poison_rate <= 1.0:
        raise ValueError(f"poison_rate must be in [0, 1]; got {poison_rate}")
    poisons = ("nan-q", "inf-z", "real-z", "empty")
    for i in range(num):
        rng = np.random.default_rng(np.random.PCG64((seed, i)))
        n = int(np.clip(np.round(rng.lognormal(np.log(median_n), sigma)),
                        n_min, n_max if n_max is not None else np.inf))
        z, q = particles(dist, n, seed=int(rng.integers(1 << 30)))
        z = np.asarray(z)
        q = np.asarray(q)
        kind = "ok"
        if poison_rate and rng.uniform() < poison_rate:
            kind = poisons[int(rng.integers(len(poisons)))]
            if kind == "nan-q":
                q = q.copy()
                q[int(rng.integers(n))] = np.nan
            elif kind == "inf-z":
                z = z.copy()
                z[int(rng.integers(n))] = np.inf + 0j
            elif kind == "real-z":
                z = z.real.copy()
            elif kind == "empty":
                z = z[:0]
                q = q[:0]
        yield n, z, q, kind


class Prefetcher:
    """Background-thread batch prefetch (depth-k queue)."""

    def __init__(self, fn, start_step: int = 0, depth: int = 2):
        self._fn = fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self._q.put((s, self._fn(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def get(self):
        return self._q.get()

    def close(self):
        self._stop.set()

from .synthetic import (DataConfig, Prefetcher, lm_batch, particles,
                        ragged_requests)

__all__ = ["DataConfig", "Prefetcher", "lm_batch", "particles",
           "ragged_requests"]

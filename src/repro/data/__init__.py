from .synthetic import (DataConfig, lm_batch, batch_specs, particles,
                        Prefetcher)

__all__ = ["DataConfig", "lm_batch", "batch_specs", "particles", "Prefetcher"]

from .synthetic import DataConfig, Prefetcher, lm_batch, particles

__all__ = ["DataConfig", "Prefetcher", "lm_batch", "particles"]

"""`FmmSolver` — the production front-end over the FMM pipeline.

One object wraps the whole paper pipeline (sort + connect + upward +
downward + evaluate) behind a jit-able entry point:

    solver = FmmSolver.build(cfg, backend="auto")   # cached per config
    phi = solver.apply(z, q)                        # one problem
    phib = solver.apply_batched(zb, qb)             # (B, N) -> (B, N)
    solver = solver.tune(z_sample)                  # fit the list caps

Time-stepping workloads (vortex methods: particles move a little each
step, topology must be refreshed thousands of times) split ``apply`` at
the topology/evaluation seam:

    plan = solver.refresh(z, q)     # device-resident sort + connect only
    phi = solver.apply_plan(plan)   # upward/downward/evaluation

``build`` memoizes solvers by ``(FmmConfig, backend)`` so repeated calls
share one compiled program — the plan cache. ``apply_batched`` vmaps the
single-problem pipeline over a leading batch axis: because *all*
adaptivity lives in the contents of statically-shaped padded lists,
B independent problems of the same config are one XLA program with a
batch dimension — the "millions of users" serving shape. On the pallas
backend the kernels are *batch-native*: their custom batching rules
lower the vmap onto batch-major (B, ...) kernel grids, so the batched
entry point keeps the fused-launch pipeline (one launch per phase for
the whole batch) instead of downgrading to the jnp sweeps. The batch
shares one connectivity-cap budget; size it with ``tune`` on a 2-D
sample; ``apply_batched_checked`` max-reduces the overflow scalar
across the batch.

Backends (``repro.solver.backends``) swap the hot phases between the
Pallas TPU kernels and the pure-jnp reference sweeps per phase.
"""
from __future__ import annotations

import copy
import warnings
from collections import OrderedDict
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import FmmConfig
from ..core.fmm import (HEALTH_CLASSES, FmmPlan, Health, fmm_build,
                        fmm_evaluate, health_of)
from ..core.topology import connectivity_stats
from ..errors import (BackendDowngradeWarning, CapOverflowError, DTypeError,
                      NonFiniteInputError, NonFiniteOutputError, ShapeError)
from .autotune import TuneResult, tune_caps, tune_tiles
from .backends import Backend, get_backend

# LRU of compiled solvers, keyed by (cfg, resolved backend name) — so
# "auto" shares the entry of whatever backend it resolves to. Bounded:
# per-workload tuning in a long-lived service mints fresh configs, and
# each solver pins up to six compiled XLA programs (entry points +
# health twins). Eviction (and cache_clear) releases those programs via
# ``_release_executables`` so they cannot strand device memory; evicted
# instances stay usable by existing holders — the next call re-traces.
# Hit/miss/eviction traffic is observable via ``FmmSolver.cache_info()``
# (the keyed-executable-cache seam the serving plane builds on,
# ``repro.serve.cache``).
_CACHE: OrderedDict = OrderedDict()
_CACHE_MAX = 64
_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


class CacheInfo(NamedTuple):
    """``FmmSolver.cache_info()`` snapshot (functools.lru_cache idiom)."""

    hits: int
    misses: int
    maxsize: int
    currsize: int
    evictions: int


def host_health(health: Health) -> dict:
    """ONE ``device_get`` of the in-graph health plane, reduced across a
    leading batch axis if present: margins min per class, overflow max,
    non-finite flags any. Returns plain-python values."""
    margins, overflow, nf_in, nf_out = (np.asarray(x) for x in
                                        jax.device_get(health))
    if margins.ndim == 2:       # batched: worst row per class
        margins = margins.min(axis=0)
    return {
        "margins": {c: int(m) for c, m in zip(HEALTH_CLASSES, margins)},
        "overflow": int(overflow.max()),
        "nonfinite_input": bool(nf_in.any()),
        "nonfinite_output": bool(nf_out.any()),
    }


def raise_unhealthy(h: dict, cfg: FmmConfig, entry: str = "apply") -> None:
    """Raise the typed error matching a ``host_health`` dict (no-op when
    healthy). Order: garbage input first, then dropped interactions,
    then non-finite output — the most actionable diagnosis wins."""
    if h["nonfinite_input"]:
        raise NonFiniteInputError(
            f"{entry}: z or q contain NaN/Inf — refusing to compute on "
            "non-finite input")
    if h["overflow"]:
        neg = {c: m for c, m in h["margins"].items() if m < 0}
        raise CapOverflowError(
            f"{entry}: connectivity caps overflow by {h['overflow']} "
            f"(strong_cap={cfg.strong_cap}, weak_cap={cfg.weak_cap}; "
            f"negative margins {neg}); re-tune on this workload",
            margins=h["margins"], overflow=h["overflow"])
    if h["nonfinite_output"]:
        raise NonFiniteOutputError(
            f"{entry}: phi contains NaN/Inf on finite input — kernel or "
            "expansion fault (degrade the evaluation phase to the "
            "reference backend, or use apply_guarded)")


class FmmSolver:
    """Compiled FMM evaluator for one ``FmmConfig`` + backend choice.

    Prefer ``FmmSolver.build`` over the constructor: ``build`` returns
    the cached instance (and its already-compiled XLA program) for a
    config seen before.
    """

    def __init__(self, cfg: FmmConfig, backend: str = "auto"):
        self.cfg = cfg
        self.backend_name = backend
        self.backend: Backend = get_backend(backend, cfg)
        if not self.backend.supports(cfg):
            raise NotImplementedError(
                f"backend {self.backend.name!r} does not support "
                f"kernel={cfg.kernel!r}")
        self._impls = self.backend.phase_impls(cfg)
        self._topo = self.backend.topology_impls(cfg)
        # Batched path (the three-way batched-dispatch contract, see
        # repro.solver.backends): "native" hooks lower jax.vmap onto
        # batch-major kernel grids, "vmap" hooks batch as plain jnp —
        # both serve batches through the backend's own hooks. Only a
        # "fallback" backend downgrades to the reference sweeps (same
        # answer, jnp path).
        if self.backend.batched_dispatch == "fallback":
            ref = get_backend("reference")
            batched_impls = ref.phase_impls(cfg)
            batched_topo = ref.topology_impls(cfg)
            batched_name = ref.name
        else:
            batched_impls, batched_topo = self._impls, self._topo
            batched_name = self.backend.name
        # Record what each entry point ACTUALLY runs, so benchmark and
        # serving numbers cannot silently be attributed to the wrong
        # backend (the batched downgrade also warns once, below).
        self.dispatched = {
            "apply": self.backend.name,
            "apply_batched": batched_name,
        }
        self._warned_batched_fallback = False
        # trace counters: the refresh/apply entry points are compiled
        # once per solver; re-tracing on a steady-shape time-stepping
        # loop would be a plan-cache bug (asserted in tests).
        self.trace_counts = {"build": 0, "evaluate": 0}
        self._apply = jax.jit(self._make_core(self._impls, self._topo))
        self._apply_batched = jax.jit(jax.vmap(
            self._make_core(batched_impls, batched_topo)))
        # health twins: same pipeline, plus the in-graph health plane —
        # ONE launch serves phi AND the overflow/non-finite diagnosis,
        # so the checked/guarded entry points never pay a second build.
        self._apply_health = jax.jit(
            self._make_core(self._impls, self._topo, with_health=True))
        self._apply_batched_health = jax.jit(jax.vmap(
            self._make_core(batched_impls, batched_topo, with_health=True)))
        self._refresh = jax.jit(self._make_build(self._topo))
        self._apply_plan = jax.jit(self._make_evaluate(self._impls))
        self.tune_result: Optional[TuneResult] = None

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, cfg: FmmConfig, backend: str = "auto") -> "FmmSolver":
        """Cached constructor: one solver (and compiled plan) per
        ``(cfg, resolved backend)``."""
        key = (cfg, get_backend(backend, cfg).name)
        solver = _CACHE.get(key)
        if solver is None:
            _CACHE_STATS["misses"] += 1
            solver = _CACHE[key] = cls(cfg, backend)
            while len(_CACHE) > _CACHE_MAX:
                _, evicted = _CACHE.popitem(last=False)
                _CACHE_STATS["evictions"] += 1
                evicted._release_executables()
        else:
            _CACHE_STATS["hits"] += 1
            _CACHE.move_to_end(key)
        return solver

    @classmethod
    def cache_clear(cls) -> None:
        for solver in _CACHE.values():
            solver._release_executables()
        _CACHE.clear()
        _CACHE_STATS.update(hits=0, misses=0, evictions=0)

    @classmethod
    def cache_size(cls) -> int:
        return len(_CACHE)

    def _release_executables(self) -> None:
        """Drop this solver's compiled XLA programs (ALL jitted entry
        points, health twins included). Called on LRU eviction and on
        ``cache_clear`` so an evicted solver cannot strand device
        memory behind jit's trace cache: an evicted instance stays
        *usable* by existing holders — the next call just re-traces.
        """
        for fn in (self._apply, self._apply_batched, self._apply_health,
                   self._apply_batched_health, self._refresh,
                   self._apply_plan):
            fn.clear_cache()

    def _compiled_program_count(self) -> int:
        """How many compiled programs this solver currently pins across
        its jitted entry points (the eviction-release regression gate)."""
        return sum(fn._cache_size() for fn in
                   (self._apply, self._apply_batched, self._apply_health,
                    self._apply_batched_health, self._refresh,
                    self._apply_plan))

    @classmethod
    def cache_info(cls) -> CacheInfo:
        """Hit/miss/eviction counters of the ``build`` plan cache (the
        ``functools.lru_cache`` idiom). Ragged production traffic that
        churns configs shows up here as eviction pressure."""
        return CacheInfo(hits=_CACHE_STATS["hits"],
                         misses=_CACHE_STATS["misses"],
                         maxsize=_CACHE_MAX, currsize=len(_CACHE),
                         evictions=_CACHE_STATS["evictions"])

    def _make_build(self, topo: dict):
        cfg = self.cfg

        def build(z: jax.Array, q: jax.Array) -> FmmPlan:
            self.trace_counts["build"] += 1
            return fmm_build(z, q, cfg, **topo)

        return build

    def _make_evaluate(self, impls: dict):
        cfg = self.cfg

        def evaluate(plan: FmmPlan) -> jax.Array:
            self.trace_counts["evaluate"] += 1
            phi_sorted = fmm_evaluate(plan, cfg, **impls)
            out = jnp.zeros_like(phi_sorted)
            return out.at[plan.tree.perm].set(phi_sorted)

        return evaluate

    def _make_core(self, impls: dict, topo: dict, with_health: bool = False):
        cfg = self.cfg

        def core(z: jax.Array, q: jax.Array) -> jax.Array:
            plan = fmm_build(z, q, cfg, **topo)
            phi_sorted = fmm_evaluate(plan, cfg, **impls)
            out = jnp.zeros_like(phi_sorted)
            phi = out.at[plan.tree.perm].set(phi_sorted)
            if with_health:
                return phi, health_of(plan, z, q, phi)
            return phi

        return core

    # -- evaluation ---------------------------------------------------------

    def apply(self, z: jax.Array, q: jax.Array) -> jax.Array:
        """phi_i = sum_{j != i} G(z_i, x_j) for one problem; input order.

        Trusts the caps (pure jit path): an input whose interaction
        lists exceed ``strong_cap``/``weak_cap`` silently drops
        interactions. Size the caps with ``tune`` on a representative
        sample, and use ``apply_checked``/``apply_guarded`` (or monitor
        ``stats``) when production inputs may drift from it.
        """
        self._validate(z, q, "apply")
        return self._apply(z, q)

    def apply_with_health(self, z: jax.Array, q: jax.Array):
        """``apply`` plus the in-graph health plane: ``(phi, Health)``
        from ONE compiled launch — overflow margins per interaction-list
        class and non-finite input/output flags ride alongside phi, so
        checking execution health costs one ``device_get``, not a second
        eager topology build. The guarded ladder (``repro.solver.guard``)
        builds on this entry point."""
        self._validate(z, q, "apply_with_health")
        return self._apply_health(z, q)

    def apply_checked(self, z: jax.Array, q: jax.Array) -> jax.Array:
        """``apply`` with execution-health validation on the same launch.

        Raises the typed errors of ``repro.errors`` instead of silently
        returning a wrong answer: ``CapOverflowError`` when interactions
        were dropped, ``NonFiniteInputError``/``NonFiniteOutputError``
        for NaN/Inf in, resp. out. Costs one ``device_get`` over
        ``apply`` — the health plane is computed in-graph."""
        phi, health = self.apply_with_health(z, q)
        raise_unhealthy(host_health(health), self.cfg, "apply_checked")
        return phi

    def apply_batched(self, z: jax.Array, q: jax.Array) -> jax.Array:
        """Evaluate B independent problems in one call.

        ``z``/``q``: (B, N) with the same ``FmmConfig`` (one shared cap
        budget). Returns (B, N) potentials, each row in its input order.

        Serves through the backend's own hooks — on the pallas backend
        the custom batching rules lower the vmap onto batch-major kernel
        grids, so B problems are still one launch per fused phase. Only
        a ``batched_dispatch="fallback"`` backend downgrades to the
        reference sweeps; the downgrade is recorded in
        ``self.dispatched["apply_batched"]`` and warned about once per
        solver.

        Like ``apply``, trusts the caps: an overflowing batch member
        silently drops interactions. ``apply_batched_checked`` adds the
        batch-wide overflow guard.
        """
        self._validate_batched(z, q)
        self._warn_batched_fallback()
        return self._apply_batched(z, q)

    def apply_batched_with_health(self, z: jax.Array, q: jax.Array):
        """``apply_batched`` plus the per-row health plane:
        ``(phi (B, N), Health)`` with every health field carrying a
        leading B axis — one compiled launch, reduce with
        ``host_health``."""
        self._validate_batched(z, q)
        self._warn_batched_fallback()
        return self._apply_batched_health(z, q)

    def apply_batched_checked(self, z: jax.Array, q: jax.Array) -> jax.Array:
        """``apply_batched`` with execution-health validation across the
        whole batch, on the same launch. Health is reduced over the B
        problems (overflow max, margins min, non-finite any), so a
        single unhealthy batch member raises the same typed error
        ``apply_checked`` gives one problem — instead of silently
        returning truncated potentials for that row."""
        phi, health = self.apply_batched_with_health(z, q)
        raise_unhealthy(host_health(health), self.cfg,
                        "apply_batched_checked")
        return phi

    def _warn_batched_fallback(self) -> None:
        if (self.dispatched["apply_batched"] != self.backend.name
                and not self._warned_batched_fallback):
            self._warned_batched_fallback = True
            warnings.warn(
                f"backend {self.backend.name!r} declares "
                "batched_dispatch='fallback': apply_batched dispatches "
                f"the {self.dispatched['apply_batched']!r} sweeps instead "
                "(same answer; do not attribute batched timings to "
                f"{self.backend.name!r})", BackendDowngradeWarning,
                stacklevel=3)

    # -- argument validation (typed errors, repro.errors) -------------------

    def _validate_dtypes(self, z, q, entry: str) -> None:
        zd = np.dtype(getattr(z, "dtype", np.asarray(z).dtype))
        qd = np.dtype(getattr(q, "dtype", np.asarray(q).dtype))
        want = np.dtype(self.cfg.complex_dtype)
        if not np.issubdtype(zd, np.complexfloating):
            raise DTypeError(
                f"{entry} wants complex positions z = x + iy; got real "
                f"{zd.name} — a real-valued position array is a "
                "complex-vs-real confusion (pass z.astype(complex))")
        if not np.issubdtype(qd, np.complexfloating):
            raise DTypeError(
                f"{entry} wants complex charges q (the potential is "
                f"complex); got {qd.name} — add 0j (q.astype(complex))")
        if zd.itemsize < want.itemsize or qd.itemsize < want.itemsize:
            raise DTypeError(
                f"{entry}: {zd.name}/{qd.name} input into a "
                f"dtype={self.cfg.dtype!r} config would silently lose the "
                f"configured precision; cast to {want.name} (or build an "
                "f32 config)")

    def _validate(self, z, q, entry: str) -> None:
        n = self.cfg.n
        zs, qs = getattr(z, "shape", ()), getattr(q, "shape", ())
        if zs != (n,) or qs != (n,):
            raise ShapeError(
                f"{entry} wants z and q of shape ({n},); got z{zs} q{qs}")
        self._validate_dtypes(z, q, entry)

    def _validate_batched(self, z: jax.Array, q: jax.Array) -> None:
        if getattr(z, "ndim", 0) != 2:
            raise ShapeError(
                f"apply_batched wants (B, N); got {getattr(z, 'shape', ())}")
        if z.shape[-1] != self.cfg.n:
            raise ShapeError(f"N={z.shape[-1]} != cfg.n={self.cfg.n}")
        if q.shape != z.shape:
            raise ShapeError(
                f"apply_batched wants q of shape {z.shape}; got {q.shape}")
        self._validate_dtypes(z, q, "apply_batched")

    def refresh(self, z: jax.Array, q: jax.Array) -> FmmPlan:
        """Rebuild tree + connectivity for moved particles — the cheap
        per-step topology update of a time-stepping workload.

        Compiled once per solver (same static caps/tiling as ``apply``):
        after the first call, refreshing perturbed positions costs one
        device-resident sort+connect launch sequence — no re-trace, no
        re-compile (``trace_counts["build"]`` pins this in tests).
        Feed the plan to ``apply_plan``; check ``plan.conn.overflow``
        (one scalar) to monitor cap drift as particles move.
        """
        self._validate(z, q, "refresh")
        return self._refresh(z, q)

    def apply_plan(self, plan: FmmPlan) -> jax.Array:
        """Evaluate on a prebuilt plan (from ``refresh``); input order.

        ``refresh`` + ``apply_plan`` is ``apply`` split at the
        topology/evaluation seam, so a time-stepper can rebuild the plan
        every step, inspect it (overflow, stats) without extra builds,
        or evaluate one plan several times."""
        return self._apply_plan(plan)

    def plan(self, z: jax.Array, q: jax.Array) -> FmmPlan:
        """Topological phase only (tree + connectivity) for inspection."""
        return self.refresh(z, q)   # shares refresh's shape validation

    def stats(self, z: jax.Array, q: jax.Array) -> dict:
        """Connectivity stats (incl. ``overflow``) for one problem."""
        return connectivity_stats(self.plan(z, q).conn)

    def guarded(self, **kwargs) -> "GuardedSolver":  # noqa: F821
        """Wrap this solver's config/backend in the guarded-execution
        recovery ladder (``repro.solver.guard.GuardedSolver``): detect
        via the in-graph health plane, recover by cap escalation /
        per-phase degradation / direct summation, never silently
        corrupt. Keyword args forward to ``GuardedSolver``."""
        from .guard import GuardedSolver  # local: guard imports solver
        return GuardedSolver(self.cfg, self.backend_name, **kwargs)

    # -- autotuning ---------------------------------------------------------

    def tune(self, z_sample: jax.Array, q_sample: jax.Array | None = None,
             *, margin: float = 1.25, round_to: int = 8,
             max_grow: int = 6, tiles: bool = True,
             tile_timer=None) -> "FmmSolver":
        """Fit ``strong_cap``/``weak_cap`` — and the Pallas kernel tiling
        (``tile_boxes``/``stage_width``) — to a workload sample.

        ``z_sample`` may be (N,) or (B, N) — a batch tunes the shared cap
        budget to its worst row. With ``tiles=True`` the tile knobs are
        tuned at the tuned caps (timing sweep on a compiling backend,
        lane heuristic otherwise; ``tile_timer`` injects a custom
        ``(z, q, cfg) -> seconds`` measurement). Returns the (cached)
        solver for the tuned config, with ``tune_result`` attached —
        ``tune_result.cfg`` carries the tile settings alongside the caps,
        ``tune_result.tile_trials`` the sweep.
        """
        result = tune_caps(z_sample, q_sample, self.cfg, margin=margin,
                           round_to=round_to, max_grow=max_grow)
        if tiles:
            tiled_cfg, tile_trials = tune_tiles(
                z_sample, q_sample, result.cfg,
                backend=self.backend_name, timer=tile_timer)
            result = result._replace(cfg=tiled_cfg,
                                     tile_trials=tuple(tile_trials))
        # Shallow copy: shares the cached compiled programs but carries
        # this caller's tune_result — concurrent tuners that land on the
        # same tuned config must not clobber each other's stats.
        tuned = copy.copy(FmmSolver.build(result.cfg, self.backend_name))
        result = result._replace(
            dispatched=tuple(sorted(tuned.dispatched.items())))
        tuned.tune_result = result
        return tuned

"""`FmmSolver` — the production front-end over the FMM pipeline.

One object wraps the whole paper pipeline (sort + connect + upward +
downward + evaluate) behind a jit-able entry point:

    solver = FmmSolver.build(cfg, backend="auto")   # cached per config
    phi = solver.apply(z, q)                        # one problem
    phib = solver.apply_batched(zb, qb)             # (B, N) -> (B, N)
    solver = solver.tune(z_sample)                  # fit the list caps

Time-stepping workloads (vortex methods: particles move a little each
step, topology must be refreshed thousands of times) split ``apply`` at
the topology/evaluation seam:

    plan = solver.refresh(z, q)     # device-resident sort + connect only
    phi = solver.apply_plan(plan)   # upward/downward/evaluation

``build`` memoizes solvers by ``(FmmConfig, backend)`` so repeated calls
share one compiled program — the plan cache. ``apply_batched`` vmaps the
single-problem pipeline over a leading batch axis: because *all*
adaptivity lives in the contents of statically-shaped padded lists,
B independent problems of the same config are one XLA program with a
batch dimension — the "millions of users" serving shape. On the pallas
backend the kernels are *batch-native*: their custom batching rules
lower the vmap onto batch-major (B, ...) kernel grids, so the batched
entry point keeps the fused-launch pipeline (one launch per phase for
the whole batch) instead of downgrading to the jnp sweeps. The batch
shares one connectivity-cap budget; size it with ``tune`` on a 2-D
sample; ``apply_batched_checked`` max-reduces the overflow scalar
across the batch.

Backends (``repro.solver.backends``) swap the hot phases between the
Pallas TPU kernels and the pure-jnp reference sweeps per phase.
"""
from __future__ import annotations

import copy
import warnings
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.config import FmmConfig
from ..core.fmm import FmmPlan, fmm_build, fmm_evaluate
from ..core.topology import connectivity_stats
from .autotune import TuneResult, tune_caps, tune_tiles
from .backends import Backend, get_backend

# LRU of compiled solvers, keyed by (cfg, resolved backend name) — so
# "auto" shares the entry of whatever backend it resolves to. Bounded:
# per-workload tuning in a long-lived service mints fresh configs, and
# each solver pins two compiled XLA programs. Evicted instances stay
# usable by existing holders; only the cache forgets them.
_CACHE: OrderedDict = OrderedDict()
_CACHE_MAX = 64


class FmmSolver:
    """Compiled FMM evaluator for one ``FmmConfig`` + backend choice.

    Prefer ``FmmSolver.build`` over the constructor: ``build`` returns
    the cached instance (and its already-compiled XLA program) for a
    config seen before.
    """

    def __init__(self, cfg: FmmConfig, backend: str = "auto"):
        self.cfg = cfg
        self.backend_name = backend
        self.backend: Backend = get_backend(backend, cfg)
        if not self.backend.supports(cfg):
            raise NotImplementedError(
                f"backend {self.backend.name!r} does not support "
                f"kernel={cfg.kernel!r}")
        self._impls = self.backend.phase_impls(cfg)
        self._topo = self.backend.topology_impls(cfg)
        # Batched path (the three-way batched-dispatch contract, see
        # repro.solver.backends): "native" hooks lower jax.vmap onto
        # batch-major kernel grids, "vmap" hooks batch as plain jnp —
        # both serve batches through the backend's own hooks. Only a
        # "fallback" backend downgrades to the reference sweeps (same
        # answer, jnp path).
        if self.backend.batched_dispatch == "fallback":
            ref = get_backend("reference")
            batched_impls = ref.phase_impls(cfg)
            batched_topo = ref.topology_impls(cfg)
            batched_name = ref.name
        else:
            batched_impls, batched_topo = self._impls, self._topo
            batched_name = self.backend.name
        # Record what each entry point ACTUALLY runs, so benchmark and
        # serving numbers cannot silently be attributed to the wrong
        # backend (the batched downgrade also warns once, below).
        self.dispatched = {
            "apply": self.backend.name,
            "apply_batched": batched_name,
        }
        self._warned_batched_fallback = False
        # trace counters: the refresh/apply entry points are compiled
        # once per solver; re-tracing on a steady-shape time-stepping
        # loop would be a plan-cache bug (asserted in tests).
        self.trace_counts = {"build": 0, "evaluate": 0}
        self._apply = jax.jit(self._make_core(self._impls, self._topo))
        self._apply_batched = jax.jit(jax.vmap(
            self._make_core(batched_impls, batched_topo)))
        self._batched_overflow = jax.jit(jax.vmap(
            self._make_overflow(batched_topo)))
        self._refresh = jax.jit(self._make_build(self._topo))
        self._apply_plan = jax.jit(self._make_evaluate(self._impls))
        self.tune_result: Optional[TuneResult] = None

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, cfg: FmmConfig, backend: str = "auto") -> "FmmSolver":
        """Cached constructor: one solver (and compiled plan) per
        ``(cfg, resolved backend)``."""
        key = (cfg, get_backend(backend, cfg).name)
        solver = _CACHE.get(key)
        if solver is None:
            solver = _CACHE[key] = cls(cfg, backend)
            while len(_CACHE) > _CACHE_MAX:
                _CACHE.popitem(last=False)
        else:
            _CACHE.move_to_end(key)
        return solver

    @classmethod
    def cache_clear(cls) -> None:
        _CACHE.clear()

    @classmethod
    def cache_size(cls) -> int:
        return len(_CACHE)

    def _make_build(self, topo: dict):
        cfg = self.cfg

        def build(z: jax.Array, q: jax.Array) -> FmmPlan:
            self.trace_counts["build"] += 1
            return fmm_build(z, q, cfg, **topo)

        return build

    def _make_overflow(self, topo: dict):
        cfg = self.cfg

        def overflow(z: jax.Array, q: jax.Array) -> jax.Array:
            return fmm_build(z, q, cfg, **topo).conn.overflow

        return overflow

    def _make_evaluate(self, impls: dict):
        cfg = self.cfg

        def evaluate(plan: FmmPlan) -> jax.Array:
            self.trace_counts["evaluate"] += 1
            phi_sorted = fmm_evaluate(plan, cfg, **impls)
            out = jnp.zeros_like(phi_sorted)
            return out.at[plan.tree.perm].set(phi_sorted)

        return evaluate

    def _make_core(self, impls: dict, topo: dict):
        cfg = self.cfg

        def core(z: jax.Array, q: jax.Array) -> jax.Array:
            plan = fmm_build(z, q, cfg, **topo)
            phi_sorted = fmm_evaluate(plan, cfg, **impls)
            out = jnp.zeros_like(phi_sorted)
            return out.at[plan.tree.perm].set(phi_sorted)

        return core

    # -- evaluation ---------------------------------------------------------

    def apply(self, z: jax.Array, q: jax.Array) -> jax.Array:
        """phi_i = sum_{j != i} G(z_i, x_j) for one problem; input order.

        Trusts the caps (pure jit path): an input whose interaction
        lists exceed ``strong_cap``/``weak_cap`` silently drops
        interactions. Size the caps with ``tune`` on a representative
        sample, and use ``apply_checked`` (or monitor ``stats``) when
        production inputs may drift from it.
        """
        return self._apply(z, q)

    def apply_checked(self, z: jax.Array, q: jax.Array) -> jax.Array:
        """``apply`` plus cap-overflow validation (one extra eager
        topological build). Raises RuntimeError instead of silently
        dropping interactions when the input exceeds the caps."""
        stats = self.stats(z, q)
        if stats["overflow"]:
            raise RuntimeError(
                f"connectivity caps overflow by {stats['overflow']} "
                f"(strong_cap={self.cfg.strong_cap}, "
                f"weak_cap={self.cfg.weak_cap}); re-tune on this workload")
        return self._apply(z, q)

    def apply_batched(self, z: jax.Array, q: jax.Array) -> jax.Array:
        """Evaluate B independent problems in one call.

        ``z``/``q``: (B, N) with the same ``FmmConfig`` (one shared cap
        budget). Returns (B, N) potentials, each row in its input order.

        Serves through the backend's own hooks — on the pallas backend
        the custom batching rules lower the vmap onto batch-major kernel
        grids, so B problems are still one launch per fused phase. Only
        a ``batched_dispatch="fallback"`` backend downgrades to the
        reference sweeps; the downgrade is recorded in
        ``self.dispatched["apply_batched"]`` and warned about once per
        solver.

        Like ``apply``, trusts the caps: an overflowing batch member
        silently drops interactions. ``apply_batched_checked`` adds the
        batch-wide overflow guard.
        """
        self._validate_batched(z, q)
        if (self.dispatched["apply_batched"] != self.backend.name
                and not self._warned_batched_fallback):
            self._warned_batched_fallback = True
            warnings.warn(
                f"backend {self.backend.name!r} declares "
                "batched_dispatch='fallback': apply_batched dispatches "
                f"the {self.dispatched['apply_batched']!r} sweeps instead "
                "(same answer; do not attribute batched timings to "
                f"{self.backend.name!r})", RuntimeWarning, stacklevel=2)
        return self._apply_batched(z, q)

    def apply_batched_checked(self, z: jax.Array, q: jax.Array) -> jax.Array:
        """``apply_batched`` plus cap-overflow validation across the
        whole batch (one extra batched topological build). The overflow
        scalar is max-reduced over the B problems, so a single
        overflowing batch member raises RuntimeError — the same re-tune
        error ``apply_checked`` gives one problem — instead of silently
        returning truncated potentials for that row."""
        self._validate_batched(z, q)
        overflow = int(jax.device_get(
            jnp.max(self._batched_overflow(z, q))))
        if overflow:
            raise RuntimeError(
                f"connectivity caps overflow by {overflow} on the worst "
                f"batch member (strong_cap={self.cfg.strong_cap}, "
                f"weak_cap={self.cfg.weak_cap}); re-tune on this workload")
        return self.apply_batched(z, q)

    def _validate_batched(self, z: jax.Array, q: jax.Array) -> None:
        if z.ndim != 2:
            raise ValueError(f"apply_batched wants (B, N); got {z.shape}")
        if z.shape[-1] != self.cfg.n:
            raise ValueError(f"N={z.shape[-1]} != cfg.n={self.cfg.n}")
        if q.shape != z.shape:
            raise ValueError(
                f"apply_batched wants q of shape {z.shape}; got {q.shape}")

    def refresh(self, z: jax.Array, q: jax.Array) -> FmmPlan:
        """Rebuild tree + connectivity for moved particles — the cheap
        per-step topology update of a time-stepping workload.

        Compiled once per solver (same static caps/tiling as ``apply``):
        after the first call, refreshing perturbed positions costs one
        device-resident sort+connect launch sequence — no re-trace, no
        re-compile (``trace_counts["build"]`` pins this in tests).
        Feed the plan to ``apply_plan``; check ``plan.conn.overflow``
        (one scalar) to monitor cap drift as particles move.
        """
        if z.shape != (self.cfg.n,) or q.shape != (self.cfg.n,):
            raise ValueError(
                f"refresh wants z and q of shape ({self.cfg.n},); got "
                f"z{z.shape} q{q.shape}")
        return self._refresh(z, q)

    def apply_plan(self, plan: FmmPlan) -> jax.Array:
        """Evaluate on a prebuilt plan (from ``refresh``); input order.

        ``refresh`` + ``apply_plan`` is ``apply`` split at the
        topology/evaluation seam, so a time-stepper can rebuild the plan
        every step, inspect it (overflow, stats) without extra builds,
        or evaluate one plan several times."""
        return self._apply_plan(plan)

    def plan(self, z: jax.Array, q: jax.Array) -> FmmPlan:
        """Topological phase only (tree + connectivity) for inspection."""
        return self.refresh(z, q)   # shares refresh's shape validation

    def stats(self, z: jax.Array, q: jax.Array) -> dict:
        """Connectivity stats (incl. ``overflow``) for one problem."""
        return connectivity_stats(self.plan(z, q).conn)

    # -- autotuning ---------------------------------------------------------

    def tune(self, z_sample: jax.Array, q_sample: jax.Array | None = None,
             *, margin: float = 1.25, round_to: int = 8,
             max_grow: int = 6, tiles: bool = True,
             tile_timer=None) -> "FmmSolver":
        """Fit ``strong_cap``/``weak_cap`` — and the Pallas kernel tiling
        (``tile_boxes``/``stage_width``) — to a workload sample.

        ``z_sample`` may be (N,) or (B, N) — a batch tunes the shared cap
        budget to its worst row. With ``tiles=True`` the tile knobs are
        tuned at the tuned caps (timing sweep on a compiling backend,
        lane heuristic otherwise; ``tile_timer`` injects a custom
        ``(z, q, cfg) -> seconds`` measurement). Returns the (cached)
        solver for the tuned config, with ``tune_result`` attached —
        ``tune_result.cfg`` carries the tile settings alongside the caps,
        ``tune_result.tile_trials`` the sweep.
        """
        result = tune_caps(z_sample, q_sample, self.cfg, margin=margin,
                           round_to=round_to, max_grow=max_grow)
        if tiles:
            tiled_cfg, tile_trials = tune_tiles(
                z_sample, q_sample, result.cfg,
                backend=self.backend_name, timer=tile_timer)
            result = result._replace(cfg=tiled_cfg,
                                     tile_trials=tuple(tile_trials))
        # Shallow copy: shares the cached compiled programs but carries
        # this caller's tune_result — concurrent tuners that land on the
        # same tuned config must not clobber each other's stats.
        tuned = copy.copy(FmmSolver.build(result.cfg, self.backend_name))
        result = result._replace(
            dispatched=tuple(sorted(tuned.dispatched.items())))
        tuned.tune_result = result
        return tuned

"""Cap autotuning: fit the padded-list budgets to the workload.

The connectivity lists are padded to static caps (``strong_cap`` /
``weak_cap``) so every shape is compile-time constant — the paper's
central design point. The caps are therefore a *performance* parameter:
too small and interactions overflow (dropped -> wrong answer, caught by
``Connectivity.overflow``); too large and every sweep pays for dead
padding. Holm, Engblom, Goude & Holmgren (arXiv:1311.1006) make the case
that such parameters should be tuned per workload at runtime rather than
hard-coded; this module is that idea for the TPU port.

``tune_caps`` runs the cheap topological phase (sort + connect, ~31% of
one evaluation) a handful of times on a sample of the workload:

  1. *grow*: double ``strong_cap`` until nothing overflows;
  2. *shrink*: read the actual per-box occupancy maxima from the
     overflow-free build and re-pad to ``margin`` times that, rounded up
     to ``round_to`` (lane-friendly);
  3. *verify*: one final build confirms ``overflow == 0`` at the shrunk
     caps.

A 2-D sample ``(B, N)`` tunes a shared cap budget across all B problems
(the ``apply_batched`` serving shape): caps are sized to the worst row.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.config import FmmConfig
from ..core.connectivity import connectivity_stats
from ..core.fmm import fmm_build


class TuneResult(NamedTuple):
    """Outcome of a cap-tuning run."""

    cfg: FmmConfig          # tuned config (overflow-free on the sample)
    stats: dict             # connectivity stats at the tuned caps
    trials: list            # [(strong_cap, weak_cap, overflow), ...]


def _round_up(x: int, m: int) -> int:
    return max(m, (x + m - 1) // m * m)


def probe_caps(z: jax.Array, q: jax.Array, cfg: FmmConfig) -> tuple[int, dict]:
    """Build tree+connectivity once; return (overflow, stats).

    ``z``/``q`` may be ``(N,)`` for one problem or ``(B, N)`` for a batch
    sharing one cap budget — stats then aggregate the worst row.
    """
    if z.ndim == 1:
        z, q = z[None], q[None]
    overflow, stats = 0, None
    for b in range(z.shape[0]):
        plan = fmm_build(z[b], q[b], cfg)
        s = connectivity_stats(jax.device_get(plan.conn))
        overflow = max(overflow, s["overflow"])
        if stats is None:
            stats = s
        else:
            stats = {k: max(stats[k], s[k]) for k in stats}
    return overflow, stats


def tune_caps(z: jax.Array, q: jax.Array | None, cfg: FmmConfig, *,
              margin: float = 1.25, round_to: int = 8,
              max_grow: int = 6) -> TuneResult:
    """Fit ``strong_cap``/``weak_cap`` to the sample; see module docstring.

    ``margin`` head-room (>= 1) absorbs drift between the tuning sample
    and production inputs; ``round_to`` keeps caps lane-friendly.
    """
    if margin < 1.0:
        raise ValueError("margin must be >= 1")
    z = jnp.asarray(z)
    q = jnp.ones(z.shape, cfg.complex_dtype) if q is None else jnp.asarray(q)

    trials: list = []
    cur = cfg
    for attempt in range(max_grow + 1):
        overflow, stats = probe_caps(z, q, cur)
        trials.append((cur.strong_cap, cur.weak_cap, overflow))
        if overflow == 0:
            break
        if attempt == max_grow:
            raise RuntimeError(
                f"connectivity still overflows by {overflow} at "
                f"strong_cap={cur.strong_cap} (after {max_grow} doublings); "
                "the sample distribution defeats the theta-criterion caps")
        cur = dataclasses.replace(cur, strong_cap=2 * cur.strong_cap,
                                  weak_cap=0)  # 0 -> 4*strong (post_init)

    strong = _round_up(int(stats["strong_max"] * margin), round_to)
    weak = _round_up(int(stats["weak_max"] * margin), round_to)
    tuned = dataclasses.replace(cur, strong_cap=strong, weak_cap=weak)

    overflow, stats = probe_caps(z, q, tuned)
    trials.append((tuned.strong_cap, tuned.weak_cap, overflow))
    if overflow != 0:  # cannot happen: caps >= measured maxima
        raise RuntimeError("tuned caps overflow; file a bug")
    return TuneResult(cfg=tuned, stats=stats, trials=trials)

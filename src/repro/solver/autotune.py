"""Workload autotuning: fit the padded-list budgets and kernel tiles.

The connectivity lists are padded to static caps (``strong_cap`` /
``weak_cap``) so every shape is compile-time constant — the paper's
central design point. The caps are therefore a *performance* parameter:
too small and interactions overflow (dropped -> wrong answer, caught by
``Connectivity.overflow``); too large and every sweep pays for dead
padding. Holm, Engblom, Goude & Holmgren (arXiv:1311.1006) make the case
that such parameters should be tuned per workload at runtime rather than
hard-coded; this module is that idea for the TPU port.

``tune_caps`` runs the cheap topological phase (sort + connect, ~31% of
one evaluation) a handful of times on a sample of the workload:

  1. *grow*: double ``strong_cap`` until nothing overflows;
  2. *shrink*: read the actual per-box occupancy maxima from the
     overflow-free build and re-pad to ``margin`` times that, rounded up
     to ``round_to`` (lane-friendly);
  3. *verify*: one final build confirms ``overflow == 0`` at the shrunk
     caps.

``tune_tiles`` picks the Pallas kernel tiling (``tile_boxes`` /
``stage_width``, DESIGN.md §2) for the tuned caps: a timing sweep of the
real end-to-end apply path when the backend compiles (on TPU), a
lane-geometry heuristic otherwise (interpret-mode timings are noise).

A 2-D sample ``(B, N)`` tunes a shared cap budget across all B problems
(the ``apply_batched`` serving shape): caps are sized to the worst row.
On a backend that serves batches through its own hooks (the batched-
dispatch contract, ``repro.solver.backends``) the tile sweep then times
the *batched* apply path — the batch-major kernel grids are what
production serves, and the best tile can differ once B problems share
the launch — while a "fallback" backend times one row as before.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core.config import FmmConfig, max_leaf_size
from ..core.fmm import fmm_build
from ..core.topology import connectivity_stats
from ..kernels.common import default_interpret
from .backends import get_backend


class TuneResult(NamedTuple):
    """Outcome of a tuning run (caps, and optionally tiles)."""

    cfg: FmmConfig          # tuned config (overflow-free on the sample)
    stats: dict             # connectivity stats at the tuned caps
    trials: list            # [(strong_cap, weak_cap, overflow), ...]
    tile_trials: tuple = ()  # ((tile_boxes, stage_width, seconds|None), ...)
    dispatched: tuple = ()   # (("apply", backend), ("apply_batched", ...)):
    #                          what the tuned solver ACTUALLY runs per
    #                          entry point (see FmmSolver.dispatched)


def _round_up(x: int, m: int) -> int:
    return max(m, (x + m - 1) // m * m)


def probe_caps(z: jax.Array, q: jax.Array, cfg: FmmConfig) -> tuple[int, dict]:
    """Build tree+connectivity once; return (overflow, stats).

    ``z``/``q`` may be ``(N,)`` for one problem or ``(B, N)`` for a batch
    sharing one cap budget — stats then aggregate the worst row.
    """
    if z.ndim == 1:
        z, q = z[None], q[None]
    overflow, stats = 0, None
    for b in range(z.shape[0]):
        plan = fmm_build(z[b], q[b], cfg)
        s = connectivity_stats(plan.conn)
        overflow = max(overflow, s["overflow"])
        if stats is None:
            stats = s
        else:
            # worst row per counter; the per-class margins aggregate min
            # (fewest slots left across the batch)
            stats = {k: ({c: min(stats[k][c], s[k][c]) for c in stats[k]}
                         if isinstance(stats[k], dict)
                         else max(stats[k], s[k]))
                     for k in stats}
    return overflow, stats


def tune_caps(z: jax.Array, q: jax.Array | None, cfg: FmmConfig, *,
              margin: float = 1.25, round_to: int = 8,
              max_grow: int = 6) -> TuneResult:
    """Fit ``strong_cap``/``weak_cap`` to the sample; see module docstring.

    ``margin`` head-room (>= 1) absorbs drift between the tuning sample
    and production inputs; ``round_to`` keeps caps lane-friendly.
    """
    if margin < 1.0:
        raise ValueError("margin must be >= 1")
    z = jnp.asarray(z)
    q = jnp.ones(z.shape, cfg.complex_dtype) if q is None else jnp.asarray(q)

    trials: list = []
    cur = cfg
    for attempt in range(max_grow + 1):
        overflow, stats = probe_caps(z, q, cur)
        trials.append((cur.strong_cap, cur.weak_cap, overflow))
        if overflow == 0:
            break
        if attempt == max_grow:
            raise RuntimeError(
                f"connectivity still overflows by {overflow} at "
                f"strong_cap={cur.strong_cap} (after {max_grow} doublings); "
                "the sample distribution defeats the theta-criterion caps")
        cur = dataclasses.replace(cur, strong_cap=2 * cur.strong_cap,
                                  weak_cap=0)  # 0 -> 4*strong (post_init)

    strong = _round_up(int(stats["strong_max"] * margin), round_to)
    weak = _round_up(int(stats["weak_max"] * margin), round_to)
    tuned = dataclasses.replace(cur, strong_cap=strong, weak_cap=weak)

    overflow, stats = probe_caps(z, q, tuned)
    trials.append((tuned.strong_cap, tuned.weak_cap, overflow))
    if overflow != 0:  # cannot happen: caps >= measured maxima
        raise RuntimeError("tuned caps overflow; file a bug")
    return TuneResult(cfg=tuned, stats=stats, trials=trials)


# ---------------------------------------------------------------------------
# kernel-tile tuning (tile_boxes / stage_width, DESIGN.md §2)
# ---------------------------------------------------------------------------

# Budget for the fused evaluation kernel's VMEM working set. TPU cores
# carry ~16 MB of VMEM; half is left for Pallas double-buffer headroom
# and the compiler's own scratch.
EVAL_VMEM_BUDGET = 8 * 2**20


def eval_fused_vmem_bytes(cfg: FmmConfig, tile_boxes: int | None = None,
                          stage_width: int | None = None) -> int:
    """VMEM working-set estimate of the fused evaluation kernel.

    Per grid step the kernel holds resident: 5 (TB, n_pad) target planes
    (positions, ranks, pre-centered), 2 (TB, P) local blocks and the
    2 (TB, n_pad) revisited phi blocks; it streams TB*SW staged source
    rows of every plane family (5 particle + 2 multipole) plus 3 (TB, SW)
    slot planes, double-buffered by Pallas (x2). The (TB, n_t, n_s)
    pairwise P2P tile lives in vector registers and is excluded.

    The estimate is *batch-invariant*: the batch-major grid gives every
    (b, i, s) step the same per-step blocks — B problems only lengthen
    the grid (DESIGN.md §2) — so this budget (and the
    ``tile_candidates`` filter built on it) holds unchanged for
    ``apply_batched``.
    """
    TB = cfg.tile_boxes if tile_boxes is None else tile_boxes
    SW = cfg.stage_width if stage_width is None else stage_width
    n_pad = -(-max_leaf_size(cfg) // 128) * 128
    P = -(-(cfg.p + 1) // 128) * 128
    itemsize = 8 if cfg.dtype == "f64" else 4
    resident = TB * (7 * n_pad + 2 * P)
    staged = TB * SW * (5 * n_pad + 2 * P) + 3 * TB * SW
    return (resident + 2 * staged) * itemsize


def tile_candidates(cfg: FmmConfig,
                    vmem_budget: int = EVAL_VMEM_BUDGET) -> list[int]:
    """Pow-2 ``tile_boxes`` candidates up to the leaf-level box count,
    filtered to tiles whose fused-evaluation working set fits the VMEM
    budget (large-leaf configs cap the useful tile)."""
    cands = [t for t in (1, 2, 4, 8, 16) if t <= cfg.nboxes] or [1]
    fit = [t for t in cands
           if eval_fused_vmem_bytes(cfg, tile_boxes=t) <= vmem_budget]
    return fit or cands[:1]


def heuristic_tiles(cfg: FmmConfig) -> FmmConfig:
    """Lane-geometry default when timing is unavailable: the largest
    pow-2 tile <= min(8 sublanes, nboxes) that keeps the fused evaluation
    kernel inside the VMEM budget fills the f32 vector registers; one
    staged slot keeps the working set minimal."""
    tb = max(t for t in tile_candidates(cfg) if t <= 8)
    return dataclasses.replace(cfg, tile_boxes=tb, stage_width=1)


def _apply_timer(backend: str, repeats: int,
                 batched: bool = False) -> Callable:
    """Time the jitted end-to-end apply path for one config (seconds).

    With ``batched=True`` the sample is (B, N) and the measured program
    is ``jax.vmap`` of the pipeline — the batch-major kernel grids the
    serving entry point actually runs."""
    from ..core.fmm import fmm_evaluate  # local: avoid cycle at import

    def timer(z, q, cfg: FmmConfig) -> float:
        be = get_backend(backend, cfg)
        impls = be.phase_impls(cfg)
        topo = be.topology_impls(cfg)

        def one(z, q):
            return fmm_evaluate(fmm_build(z, q, cfg, **topo), cfg, **impls)

        run = jax.jit(jax.vmap(one) if batched else one)
        jax.block_until_ready(run(z, q))           # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(run(z, q))
            best = min(best, time.perf_counter() - t0)
        return best

    return timer


def tune_tiles(z: jax.Array, q: jax.Array | None, cfg: FmmConfig, *,
               backend: str = "auto", repeats: int = 3,
               timer: Optional[Callable] = None
               ) -> tuple[FmmConfig, list]:
    """Pick ``tile_boxes``/``stage_width`` for this workload.

    When the resolved backend compiles Pallas kernels (pallas on a real
    TPU) — or a ``timer(z, q, cfg) -> seconds`` is injected — each
    candidate is measured on the end-to-end apply path: first the
    ``tile_boxes`` sweep at ``stage_width=1``, then the stage-width sweep
    at the winning tile. Otherwise (reference backend, or interpret mode
    where timings are noise) a lane-geometry heuristic picks the tile.

    A (B, N) sample stays batched when the backend serves batches
    through its own hooks (``batched_dispatch`` != "fallback"): the
    timer then measures the vmapped pipeline — i.e. the batch-major
    kernel grids of ``apply_batched`` — so the tile is tuned for the
    shape production runs. On a "fallback" backend the sweep times one
    row, as the batched entry would not run these kernels anyway.

    Returns ``(tuned_cfg, trials)`` with trials
    ``[(tile_boxes, stage_width, seconds|None), ...]``.
    """
    be = get_backend(backend, cfg)
    measurable = timer is not None or (be.name == "pallas"
                                       and not default_interpret())
    if not measurable:
        tuned = heuristic_tiles(cfg)
        return tuned, [(tuned.tile_boxes, tuned.stage_width, None)]

    z = jnp.asarray(z)
    batched = z.ndim == 2 and be.batched_dispatch != "fallback"
    if z.ndim == 2 and not batched:       # fallback backend: time one row
        z = z[0]
        q = None if q is None else jnp.asarray(q)[0]
    q = jnp.ones(z.shape, cfg.complex_dtype) if q is None else jnp.asarray(q)
    timer = timer or _apply_timer(be.name, repeats, batched=batched)

    trials: list = []

    def measure(tb: int, sw: int) -> float:
        c = dataclasses.replace(cfg, tile_boxes=tb, stage_width=sw)
        t = float(timer(z, q, c))
        trials.append((tb, sw, t))
        return t

    best_tb = min(tile_candidates(cfg), key=lambda tb: measure(tb, 1))
    # sw=1 was already measured in the tile sweep; reuse that time
    sw_times = {1: min(t for tb, sw, t in trials
                       if tb == best_tb and sw == 1)}
    for sw in (2, 4):
        # staged slots multiply the streamed rows: respect both the
        # operand-count bound and the fused-eval VMEM budget
        if (best_tb * sw <= 128
                and eval_fused_vmem_bytes(cfg, best_tb, sw)
                <= EVAL_VMEM_BUDGET):
            sw_times[sw] = measure(best_tb, sw)
    best_sw = min(sw_times, key=sw_times.get)
    return (dataclasses.replace(cfg, tile_boxes=best_tb,
                                stage_width=best_sw), trials)

"""Per-phase backend registry for the FMM hot paths.

The pipeline in ``repro.core.fmm`` exposes seven override hooks — the
near-field P2P sweep, the level M2L translation (per-level or fused
across all levels in one launch), the leaf L2P evaluation, the downward
P2L shift, the fused whole-evaluation-phase hook (L2P + M2P + P2P in
one launch; the evaluation phase is ~56% of the paper's GPU runtime,
Table 5.1), and the topology phase's leaf-level classification
(``fmm_build``'s ``leaf_classify_impl``). A ``Backend`` bundles one
implementation per hook; the
registry maps names to backends so callers (``FmmSolver``, benchmarks,
tests) pick by string:

  "reference"  pure-jnp oracles from ``repro.core.fmm`` (every hook None
               -> the core path runs its own sweep)
  "pallas"     the Pallas TPU kernels from ``repro.kernels`` (interpret
               mode off-TPU); both G-kernels (harmonic and log), the
               downward M2L fused into a single launch, P2L as a kernel,
               and the whole evaluation phase as ONE fused launch — no
               phase of the default config falls back to a jnp sweep
  "auto"       "pallas" on a TPU backend, "reference" otherwise —
               interpret-mode Pallas on CPU is a correctness tool, not a
               fast path

Each backend also declares its **batched-dispatch contract**
(``batched_dispatch``) — how ``FmmSolver.apply_batched`` may serve B
problems per call through its hooks:

  "native"     the hooks contain batch-native kernels with custom
               batching rules: ``jax.vmap`` lowers onto batch-major
               (B, ...) kernel grids, one launch per phase for the whole
               batch (the pallas backend)
  "vmap"       plain jnp hooks that batch under ``jax.vmap`` as-is (the
               reference backend; the default for new backends)
  "fallback"   hooks that cannot batch at all — the solver downgrades
               the batched entry point to the reference sweeps and warns

Third parties register additional backends with ``register_backend`` —
e.g. a shard_map multi-chip variant — without touching the dispatch
sites; a backend whose kernels lack batching rules declares
``batched_dispatch="fallback"``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from ..core.config import FmmConfig

# Hook signatures (matching repro.core.fmm.fmm_evaluate):
#   p2p(tree, conn, cfg, idx)            -> (n,) complex contribution
#   m2l(mult, weak, centers, cfg, rho)   -> (nbox, p+1) complex
#   m2l_fused(mult, weak, centers, cfg, rho) -> per-level list; the
#       arguments are the *per-level* sequences (one launch, all levels)
#   l2p(local, tree, cfg, idx)           -> (n,) complex
#   p2l(tree, conn, cfg, idx, rho_leaf)  -> (nbox, p+1) complex
#       contribution folded into the downward local coefficients
#   eval_fused(local, mult_leaf, tree, conn, cfg, idx) -> (n,) complex:
#       the WHOLE evaluation phase (L2P + M2P + P2P) in one launch;
#       takes precedence over p2p/l2p
#
# Topology hooks (matching repro.core.fmm.fmm_build):
#   leaf_classify(cand, valid, centers, radii, cfg) -> five keyed
#       (4**L, 4S) int32 arrays (strong, weak, p2p, p2l, m2p) for the
#       leaf-level strong/weak/swapped-theta classification
PhaseImpl = Optional[Callable]


def _platform() -> str:
    """The JAX platform driving "auto" dispatch (monkeypatchable in tests)."""
    return jax.default_backend()


#: Valid ``Backend.batched_dispatch`` values (see module docstring):
#: "native" = batch-major kernel grids behind custom batching rules,
#: "vmap" = plain-jnp hooks safe under jax.vmap, "fallback" = the
#: solver downgrades apply_batched to the reference sweeps.
BATCHED_DISPATCH = ("native", "vmap", "fallback")


@dataclasses.dataclass(frozen=True)
class Backend:
    """Named bundle of per-phase implementations (None -> core jnp path).

    ``batched_dispatch`` is the three-way batched-dispatch contract for
    ``FmmSolver.apply_batched`` (module docstring): "native" and "vmap"
    hooks serve batches directly under ``jax.vmap`` — batch-major kernel
    grids vs plain jnp batching — while "fallback" downgrades the
    batched entry point to the reference sweeps.
    ``supports(cfg)`` gates dispatch (config/kernel compatibility).
    """

    name: str
    p2p: PhaseImpl = None
    m2l: PhaseImpl = None
    l2p: PhaseImpl = None
    m2l_fused: PhaseImpl = None
    p2l: PhaseImpl = None
    eval_fused: PhaseImpl = None
    leaf_classify: PhaseImpl = None
    batched_dispatch: str = "vmap"

    def __post_init__(self):
        if self.batched_dispatch not in BATCHED_DISPATCH:
            raise ValueError(
                f"batched_dispatch={self.batched_dispatch!r} not in "
                f"{BATCHED_DISPATCH}")

    def supports(self, cfg: FmmConfig) -> bool:
        return True

    def phase_impls(self, cfg: FmmConfig) -> dict:
        """kwargs for ``fmm_evaluate`` selecting this backend's hooks."""
        return {"p2p_impl": self.p2p, "m2l_impl": self.m2l,
                "l2p_impl": self.l2p, "m2l_fused_impl": self.m2l_fused,
                "p2l_impl": self.p2l, "eval_fused_impl": self.eval_fused}

    def topology_impls(self, cfg: FmmConfig) -> dict:
        """kwargs for ``fmm_build`` selecting this backend's topology
        hooks (the sort/connect phase — paper §4.1/§4.3)."""
        return {"leaf_classify_impl": self.leaf_classify}


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    """Register (or replace) a backend under ``backend.name``."""
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> list[str]:
    return sorted(_REGISTRY) + ["auto"]


def get_backend(name: str, cfg: FmmConfig | None = None) -> Backend:
    """Resolve a backend name ("auto" needs ``cfg`` to pick per-config)."""
    if name == "auto":
        return _resolve_auto(cfg)
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def _resolve_auto(cfg: FmmConfig | None) -> Backend:
    pallas = _REGISTRY["pallas"]
    if (_platform() == "tpu"
            and (cfg is None or pallas.supports(cfg))):
        return pallas
    return _REGISTRY["reference"]


def _make_reference() -> Backend:
    return Backend(name="reference")


def _make_pallas() -> Backend:
    from ..kernels import (eval_fused_apply, l2p_apply, leaf_classify_pallas,
                           m2l_fused_apply, m2l_level_apply, p2l_apply,
                           p2p_apply)

    def p2p(tree, conn, cfg, idx):
        return p2p_apply(tree, conn, cfg, idx)

    def m2l(mult, weak, centers, cfg, rho):
        return m2l_level_apply(mult, weak, centers, cfg, rho)

    def m2l_fused(mult, weak, centers, cfg, rho):
        return m2l_fused_apply(mult, weak, centers, cfg, rho)

    def l2p(local, tree, cfg, idx):
        return l2p_apply(local, tree, cfg, idx)

    def p2l(tree, conn, cfg, idx, rho):
        return p2l_apply(tree, conn, cfg, idx, rho)

    def eval_fused(local, mult_leaf, tree, conn, cfg, idx):
        return eval_fused_apply(local, mult_leaf, tree, conn, cfg, idx)

    def leaf_classify(cand, valid, centers, radii, cfg):
        return leaf_classify_pallas(cand, valid, centers, radii, cfg)

    # batch-native: every kernel wrapper op carries a custom batching
    # rule that lowers jax.vmap onto its batch-major (B, ...) grid, so
    # apply_batched serves through these hooks at kernel speed.
    return Backend(name="pallas", p2p=p2p, m2l=m2l, l2p=l2p,
                   m2l_fused=m2l_fused, p2l=p2l, eval_fused=eval_fused,
                   leaf_classify=leaf_classify, batched_dispatch="native")


register_backend(_make_reference())
register_backend(_make_pallas())

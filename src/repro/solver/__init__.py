"""Unified FMM solver front-end: plan caching, per-phase backend
dispatch, batched multi-problem evaluation, and cap autotuning.

    from repro.solver import FmmSolver
    solver = FmmSolver.build(cfg, backend="auto").tune(z_sample)
    phi = solver.apply(z, q)
    phib = solver.apply_batched(zb, qb)
"""
from .autotune import TuneResult, probe_caps, tune_caps, tune_tiles
from .backends import (Backend, available_backends, get_backend,
                       register_backend)
from .guard import GuardAttempt, GuardedSolver, GuardReport
from .solver import CacheInfo, FmmSolver, host_health, raise_unhealthy

__all__ = [
    "FmmSolver", "CacheInfo", "host_health", "raise_unhealthy",
    "GuardedSolver", "GuardReport", "GuardAttempt",
    "Backend", "available_backends", "get_backend", "register_backend",
    "TuneResult", "probe_caps", "tune_caps", "tune_tiles",
]

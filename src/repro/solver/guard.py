"""Guarded execution: detect → recover → degrade, never silently corrupt.

The paper's adaptive discretization is only correct while the
connectivity caps hold; production inputs drift (time-stepping advects
particles, serving traffic changes distribution), and a drifted input
silently drops interactions on the trusting jit path. This module is
the robustness layer over ``FmmSolver``:

  detect    the in-graph health plane (``core.fmm.Health``) rides along
            every launch: per-class cap margins + non-finite flags, read
            with ONE ``device_get`` — no second eager topology build
  recover   ``apply_guarded`` escalates through a bounded, precompiled
            lattice of neighboring plans: per-class cap doubling (the
            margins say *which* cap to grow) with bounded recompile
            retries — the ``FmmSolver.build`` LRU is the lattice, so a
            rung compiles once and is a cache hit ever after
  degrade   a non-finite output (kernel fault) degrades per-phase: first
            the evaluation-phase hooks fall back to the reference
            sweeps, then the whole backend; the final rung is the
            O(N^2) ``core.direct`` summation, which cannot drop
            interactions and has no caps to overflow
  report    every attempt is recorded in a structured ``GuardReport``
            (rungs walked, margins seen, retries, degradations, final
            backend), and failures raise the typed errors of
            ``repro.errors`` — never a bare RuntimeError, never a
            silently wrong phi

Cf. Holm et al. (arXiv:1311.1006) — re-planning online from measured
feedback — and Agullo et al. (pipelined FMM over a runtime system) —
runtime monitors keeping long pipelines healthy. DESIGN.md §9 documents
the failure model and the cost of each rung.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from ..core.config import FmmConfig
from ..core.direct import direct_potential
from ..core.fmm import HEALTH_CLASSES, FmmPlan
from ..errors import (CapOverflowError, NonFiniteInputError,
                      RecoveryExhaustedError)
from .backends import Backend, get_backend, register_backend
from .solver import FmmSolver, host_health

#: Interaction-list classes whose padded width is ``strong_cap``.
_STRONG_CLASSES = ("strong", "p2p", "p2l", "m2p")


@dataclasses.dataclass(frozen=True)
class GuardAttempt:
    """One rung of a ladder walk: what ran and what the health plane saw."""

    rung: str                  # "primary" | "caps*2^k" | "degrade:*" | "direct"
    backend: str
    strong_cap: int
    weak_cap: int
    ok: bool
    overflow: int = 0
    margins: Optional[dict] = None          # HEALTH_CLASSES -> slots left
    nonfinite_input: bool = False
    nonfinite_output: bool = False
    note: str = ""


@dataclasses.dataclass(frozen=True)
class GuardReport:
    """Structured record of one guarded call (DESIGN.md §9).

    ``attempts`` is the full walk in order; ``retries`` counts the extra
    attempts beyond the primary; ``degradations`` the backend-degrading
    rungs taken. ``ok`` means the returned phi is trustworthy: computed
    with zero dropped interactions and finite throughout.
    """

    entry: str                                # "apply" | "apply_batched" | ...
    attempts: tuple[GuardAttempt, ...]
    final_backend: Optional[str] = None
    final_rung: Optional[str] = None

    @property
    def ok(self) -> bool:
        return bool(self.attempts) and self.attempts[-1].ok

    @property
    def retries(self) -> int:
        return max(0, len(self.attempts) - 1)

    @property
    def degradations(self) -> tuple[str, ...]:
        return tuple(a.rung for a in self.attempts
                     if a.rung.startswith("degrade:") or a.rung == "direct")

    @property
    def margins(self) -> Optional[dict]:
        return self.attempts[-1].margins if self.attempts else None

    def summary(self) -> str:
        path = " -> ".join(a.rung for a in self.attempts) or "(empty)"
        state = "ok" if self.ok else "FAILED"
        return (f"[guard:{self.entry}] {path} ({state}, "
                f"backend={self.final_backend}, retries={self.retries})")


def grow_caps(cfg: FmmConfig, margins: Optional[dict] = None) -> FmmConfig:
    """One cap-escalation step, targeted by the per-class margins: only
    the cap families that actually overflowed double (``strong_cap``
    backs the strong/p2p/p2l/m2p lists, ``weak_cap`` the M2L lists).
    The weak cap is clamped to its structural bound ``4*strong_cap``
    (weak candidates are children of the parent's strong set). With no
    margins, both caps double."""
    need_strong = (margins is None
                   or any(margins.get(c, 0) < 0 for c in _STRONG_CLASSES))
    need_weak = margins is None or margins.get("weak", 0) < 0
    strong = cfg.strong_cap * 2 if need_strong else cfg.strong_cap
    weak = cfg.weak_cap * 2 if need_weak else cfg.weak_cap
    return dataclasses.replace(cfg, strong_cap=strong,
                               weak_cap=min(weak, 4 * strong))


def degraded_eval_backend(be: Backend) -> Optional[Backend]:
    """The per-phase degradation rung: ``be`` with its evaluation-phase
    hooks (fused evaluation, P2P, L2P, downward P2L) dropped back to the
    reference sweeps, keeping the topology and M2L hooks. Registered
    under ``"<name>+ref-eval"`` so ``FmmSolver.build`` can cache its
    compiled programs like any backend. None if ``be`` has nothing to
    degrade (already the reference path)."""
    if (be.eval_fused is None and be.p2p is None and be.l2p is None
            and be.p2l is None):
        return None
    name = f"{be.name}+ref-eval"
    degraded = dataclasses.replace(be, name=name, eval_fused=None,
                                   p2p=None, l2p=None, p2l=None)
    return register_backend(degraded)


class GuardedSolver:
    """``FmmSolver`` behind the recovery ladder (module docstring).

    The guarded entry points return ``(result, GuardReport)``. A
    successful cap escalation *promotes* the escalated solver to be the
    new primary (``self.solver``), so a time-stepping loop that drifted
    past its tuned caps re-plans once and stays on the fast path —
    instead of raising (or silently corrupting) every subsequent step.

      guarded = GuardedSolver(cfg, "auto")
      phi, report = guarded.apply_guarded(z, q)
      plan, report = guarded.refresh_guarded(z, q)   # time-stepping
      phi = guarded.apply_plan(plan)

    ``max_cap_doublings`` bounds the recompile retries of the cap rung;
    ``degrade``/``direct`` gate the backend-degradation and O(N^2)
    last-resort rungs.
    """

    def __init__(self, cfg: FmmConfig, backend: str = "auto", *,
                 max_cap_doublings: int = 3, degrade: bool = True,
                 direct: bool = True):
        if max_cap_doublings < 0:
            raise ValueError("max_cap_doublings must be >= 0")
        self.backend_name = backend
        self.max_cap_doublings = max_cap_doublings
        self.allow_degrade = degrade
        self.allow_direct = direct
        self.solver = FmmSolver.build(cfg, backend)

    @property
    def cfg(self) -> FmmConfig:
        """Config of the *current* primary (escalations promote)."""
        return self.solver.cfg

    @property
    def trace_counts(self) -> dict:
        return self.solver.trace_counts

    def apply_plan(self, plan: FmmPlan) -> jax.Array:
        return self.solver.apply_plan(plan)

    # -- ladder machinery ---------------------------------------------------

    def _attempt(self, solver: FmmSolver, z, q, rung: str, attempts: list,
                 batched: bool, note: str = ""):
        """Run one rung's health-instrumented apply; record the result."""
        if batched:
            phi, health = solver.apply_batched_with_health(z, q)
        else:
            phi, health = solver.apply_with_health(z, q)
        h = host_health(health)
        ok = not (h["overflow"] or h["nonfinite_input"]
                  or h["nonfinite_output"])
        attempts.append(GuardAttempt(
            rung=rung, backend=solver.dispatched["apply"],
            strong_cap=solver.cfg.strong_cap, weak_cap=solver.cfg.weak_cap,
            ok=ok, overflow=h["overflow"], margins=h["margins"],
            nonfinite_input=h["nonfinite_input"],
            nonfinite_output=h["nonfinite_output"], note=note))
        return phi, h, ok

    def _report(self, entry: str, attempts: list) -> GuardReport:
        last = attempts[-1] if attempts else None
        return GuardReport(entry=entry, attempts=tuple(attempts),
                           final_backend=last.backend if last else None,
                           final_rung=last.rung if last else None)

    def _direct_rung(self, z, q, attempts: list, batched: bool):
        """Last resort: the O(N^2) direct summation — no caps to
        overflow, no expansions to go non-finite on finite input."""
        kernel = self.solver.cfg.kernel

        def one(zi, qi):
            return direct_potential(zi, zi, qi, kernel=kernel)

        phi = (jax.vmap(one) if batched else one)(z, q)
        finite = bool(np.all(np.isfinite(np.asarray(phi))))
        attempts.append(GuardAttempt(
            rung="direct", backend="direct",
            strong_cap=self.solver.cfg.strong_cap,
            weak_cap=self.solver.cfg.weak_cap, ok=finite,
            nonfinite_output=not finite,
            note="O(N^2) reference summation (exact, capless)"))
        return phi, finite

    def _ladder(self, z, q, entry: str, batched: bool):
        attempts: list[GuardAttempt] = []
        phi, h, ok = self._attempt(self.solver, z, q, "primary", attempts,
                                   batched)
        if ok:
            return phi, self._report(entry, attempts)
        if h["nonfinite_input"]:
            # garbage in: nothing downstream can recover — fail loud now
            raise NonFiniteInputError(
                f"{entry}: z or q contain NaN/Inf; no recovery rung can "
                "repair a non-finite input "
                f"({self._report(entry, attempts).summary()})")

        # rung 1: cap escalation through the precompiled plan lattice.
        # The per-class margins pick which cap doubles; each rung is an
        # FmmSolver.build hit after its first compile.
        solver = self.solver
        if h["overflow"]:
            for _ in range(self.max_cap_doublings):
                cfg = grow_caps(solver.cfg, h["margins"])
                solver = FmmSolver.build(cfg, self.backend_name)
                phi, h, ok = self._attempt(
                    solver, z, q, f"caps*{cfg.strong_cap}/{cfg.weak_cap}",
                    attempts, batched)
                if ok:
                    self.solver = solver      # promote: re-planned
                    return phi, self._report(entry, attempts)
                if not h["overflow"]:
                    break                     # caps fixed; other fault left

        # rung 2: per-phase degradation — only a non-finite output can be
        # cured by swapping compute paths (a reference sweep at the same
        # caps would drop the same interactions).
        if self.allow_degrade and not h["overflow"] and h["nonfinite_output"]:
            for variant in filter(None, (degraded_eval_backend(solver.backend),
                                         get_backend("reference"))):
                if variant.name == solver.backend.name:
                    continue
                deg = FmmSolver.build(solver.cfg, variant.name)
                phi, h, ok = self._attempt(
                    deg, z, q, f"degrade:{variant.name}", attempts, batched,
                    note="non-finite output: phase hooks -> reference")
                if ok:
                    return phi, self._report(entry, attempts)

        # rung 3: direct summation
        if self.allow_direct:
            phi, finite = self._direct_rung(z, q, attempts, batched)
            if finite:
                return phi, self._report(entry, attempts)

        report = self._report(entry, attempts)
        raise RecoveryExhaustedError(
            f"{entry}: every recovery rung failed — {report.summary()}",
            report=report)

    # -- guarded entry points -----------------------------------------------

    def apply_guarded(self, z: jax.Array, q: jax.Array):
        """``apply`` behind the full recovery ladder. Returns
        ``(phi, GuardReport)``; phi is never a silently-truncated or
        non-finite answer — recovery failure raises instead."""
        return self._ladder(z, q, "apply", batched=False)

    def apply_batched_guarded(self, z: jax.Array, q: jax.Array):
        """``apply_batched`` behind the ladder: health is reduced across
        the batch, so one unhealthy row escalates the whole batch (the
        batch shares one cap budget). Returns ``(phi (B, N), report)``."""
        return self._ladder(z, q, "apply_batched", batched=True)

    def refresh_guarded(self, z: jax.Array, q: jax.Array):
        """``refresh`` with automatic re-planning: when the plan's
        margins show cap overflow (particles drifted past the tuned
        budget), escalate caps — bounded doublings, each a compiled-
        once lattice neighbor — promote the escalated solver, and
        return its healthy plan. Returns ``(plan, GuardReport)``; feed
        the plan to ``apply_plan``. The steady-state cost over plain
        ``refresh`` is one host read of the margins vector."""
        attempts: list[GuardAttempt] = []
        solver = self.solver
        for _ in range(self.max_cap_doublings + 1):
            plan = solver.refresh(z, q)
            margins, overflow = jax.device_get(
                (plan.conn.margins, plan.conn.overflow))
            m = {c: int(v) for c, v in zip(HEALTH_CLASSES, margins)}
            ok = int(overflow) == 0
            attempts.append(GuardAttempt(
                rung="primary" if solver is self.solver
                else f"caps*{solver.cfg.strong_cap}/{solver.cfg.weak_cap}",
                backend=solver.dispatched["apply"],
                strong_cap=solver.cfg.strong_cap,
                weak_cap=solver.cfg.weak_cap, ok=ok,
                overflow=int(overflow), margins=m))
            if ok:
                if solver is not self.solver:
                    self.solver = solver       # promote the re-plan
                return plan, self._report("refresh", attempts)
            solver = FmmSolver.build(grow_caps(solver.cfg, m),
                                     self.backend_name)
        report = self._report("refresh", attempts)
        raise CapOverflowError(
            f"refresh: caps still overflow after {self.max_cap_doublings} "
            f"doublings — {report.summary()}",
            margins=attempts[-1].margins, overflow=attempts[-1].overflow)

    # -- lattice warm-up ----------------------------------------------------

    def precompile(self, z: jax.Array, q: jax.Array) -> list[str]:
        """Compile the ladder's neighboring plans ahead of the fault:
        the cap-doubling chain and the degradation variants all become
        ``FmmSolver.build`` cache hits, so mid-run recovery pays a plan
        switch, not a compile. Returns the list of warmed rung names."""
        warmed = []
        cfg = self.solver.cfg
        chain = [(cfg, self.backend_name)]
        for _ in range(self.max_cap_doublings):
            cfg = grow_caps(cfg)
            chain.append((cfg, self.backend_name))
        if self.allow_degrade:
            deg = degraded_eval_backend(self.solver.backend)
            if deg is not None:
                chain.append((self.solver.cfg, deg.name))
            chain.append((self.solver.cfg, "reference"))
        for rung_cfg, backend in chain:
            solver = FmmSolver.build(rung_cfg, backend)
            jax.block_until_ready(solver.apply_with_health(z, q)[0])
            warmed.append(f"{backend}@{rung_cfg.strong_cap}/"
                          f"{rung_cfg.weak_cap}")
        return warmed

from .sharding import Rules, dp_axes, maybe_shard
from .compression import (ef_allreduce, ef_allreduce_tree, init_errors,
                          quantize_int8, dequantize_int8,
                          make_compressed_value_and_grad, init_pod_errors)

__all__ = ["Rules", "dp_axes", "maybe_shard",
           "ef_allreduce", "ef_allreduce_tree", "init_errors",
           "quantize_int8", "dequantize_int8",
           "make_compressed_value_and_grad", "init_pod_errors"]

"""Gradient compression: int8 error-feedback all-reduce.

Intended placement (1000+ node design): *intra-pod* gradient reductions ride
GSPMD's native all-reduces over the fast ICI "data" axis; the *cross-pod*
reduction — the slow DCI hop — is wrapped in a ``shard_map`` over the "pod"
axis only (remaining axes stay auto-sharded), sending int8 + one f32 scale
per tensor (~4x byte reduction) with error feedback so the quantization
noise telescopes instead of accumulating (Seide et al. 2014; 1-bit Adam
lineage).

``ef_allreduce_tree`` is the pure building block; ``cross_pod_reduce``
stitches it into a pjit program via shard_map(auto=...).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


def _shard_map(f, mesh, in_specs, out_specs, manual_axes):
    """shard_map manual over ``manual_axes`` only, across jax versions
    (jax.shard_map/axis_names/check_vma landed in 0.5; 0.4 spells it
    experimental shard_map with auto= the complement and check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=frozenset(manual_axes),
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False, auto=auto)


def quantize_int8(x):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_allreduce(g, err, axis_name: str):
    """Error-feedback compressed psum of one tensor over ``axis_name``.

    The quantization scale is agreed up front (pmax of the local amax — one
    f32 scalar per tensor on the wire) so the int8 payloads of all members
    share one codebook and their integer sum dequantizes exactly.
    Returns (mean-reduced tensor f32, new local error).
    """
    y = g.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(y)), axis_name)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(y / scale), -127, 127).astype(jnp.int8)
    new_err = y - q.astype(jnp.float32) * scale
    n = jax.lax.psum(1, axis_name)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
    return summed * scale / n, new_err


def ef_allreduce_tree(grads, errors, axis_name: str):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        rg, re = ef_allreduce(g, e, axis_name)
        out_g.append(rg.astype(g.dtype))
        out_e.append(re)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)


def init_errors(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def make_compressed_value_and_grad(loss_fn, mesh):
    """Cross-pod compressed data parallelism.

    Wraps ``loss_fn(params, batch) -> scalar`` so that the gradient is
    computed *per pod* (shard_map manual over "pod"; "data"/"model" stay
    auto-partitioned inside), then mean-reduced across pods through the
    int8 error-feedback collective instead of a full-precision all-reduce
    — a ~4x cut of the slowest (cross-pod DCI) gradient traffic.

    Error-feedback state is per-pod: leaves carry a leading ``npods`` axis
    sharded over "pod" (init with ``init_pod_errors``).
    """
    def vg(params, batch, errors):
        @functools.partial(
            _shard_map, mesh=mesh,
            in_specs=(PS(), PS("pod"), PS("pod")),
            out_specs=(PS(), PS(), PS("pod")),
            manual_axes=("pod",),
        )
        def inner(p, local_batch, err):
            loss, grads = jax.value_and_grad(loss_fn)(p, local_batch)
            err = jax.tree.map(lambda e: e[0], err)          # drop pod dim
            grads, err = ef_allreduce_tree(grads, err, "pod")
            err = jax.tree.map(lambda e: e[None], err)
            return jax.lax.pmean(loss, "pod"), grads, err

        return inner(params, batch, errors)

    return vg


def init_pod_errors(params, npods: int):
    return jax.tree.map(
        lambda p: jnp.zeros((npods,) + p.shape, jnp.float32), params)

"""Mesh axes, logical->physical sharding rules, and constraint helpers.

Physical mesh axes:
  "pod"    cross-pod data parallelism (multi-pod runs only)
  "data"   in-pod data parallelism / FSDP
  "model"  tensor / expert / sequence parallelism

Logical param axes (see models/common.py) map through ``Rules``; activations
use ``batch_spec``/``act_spec`` helpers. ``maybe_shard`` is a no-op outside a
mesh context so single-device tests and smoke runs need no mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import PartitionSpec as PS


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical-axis -> mesh-axes table.

    fsdp: additionally shard the "embed" axis of params over the data axes
    (ZeRO-3 style; required for the >100B archs to fit HBM).
    """
    multi_pod: bool = False
    fsdp: bool = True

    def table(self) -> dict[str | None, Any]:
        dp = dp_axes(self.multi_pod)
        t: dict[str | None, Any] = {
            "vocab": "model",
            "heads": "model",
            "kv": "model",
            "ff": "model",
            "experts": "model",
            "layers": None,
            None: None,
        }
        t["embed"] = dp if self.fsdp else None
        return t

    def batch(self) -> PS:
        return PS(dp_axes(self.multi_pod))

    def act(self, *rest) -> PS:
        return PS(dp_axes(self.multi_pod), *rest)


ACT_DP = ("pod", "data")   # data axes for activation batch dims


def active_mesh():
    """The mesh whose axes sharding constraints may reference, or None.

    Version compat: jax >= 0.5 exposes the (abstract) mesh context via
    jax.sharding.get_abstract_mesh(); on jax < 0.5 the ``with mesh:``
    context lives in thread_resources."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources
    return thread_resources.env.physical_mesh


def maybe_shard(x, spec: PS):
    """with_sharding_constraint that degrades gracefully:

    - identity when no mesh is active (single-device tests);
    - axis names absent from the mesh are dropped (e.g. "pod" on the
      single-pod mesh);
    - axis entries whose product does not divide the corresponding array
      dim are dropped (e.g. batch 1 on a 16-wide data axis) — GSPMD's
      padding for uneven shardings is exactly what we want to avoid.

    NOTE: a PartitionSpec entry of None *forces replication* of that dim —
    always spell out the data axes on batch dims (this was a measured
    16x activation-memory bug, see EXPERIMENTS.md §Perf).
    """
    mesh = active_mesh()
    if mesh is None or mesh.empty:
        return x
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def keep(entry, dim):
        if entry is None:
            return None
        if not isinstance(entry, (tuple, list)):
            entry = (entry,)
        kept = tuple(e for e in entry if e in names)
        total = 1
        for e in kept:
            total *= sizes[e]
        if not kept or total == 0 or dim % total:
            return None
        return kept

    spec = PS(*[keep(e, d) for e, d in zip(spec, x.shape)])
    return jax.lax.with_sharding_constraint(x, spec)

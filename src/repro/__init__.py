"""repro: production-grade JAX reproduction of "Adaptive fast multipole
methods on the GPU" (Goude & Engblom, 2012) + multi-pod LM runtime for the
assigned architecture pool. See DESIGN.md."""

__version__ = "0.1.0"

"""Quickstart: evaluate the harmonic potential of 100k particles with the
adaptive FMM through the `FmmSolver` front-end, check it against direct
summation on a sample, then serve a batched (B, N) workload through
`apply_batched` — one call, one compiled program, B problems.

    PYTHONPATH=src python examples/quickstart.py [--n 100000] [--p 17]
                                                 [--batch 4]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax
jax.config.update("jax_enable_x64", True)  # f64 = the paper's precision
import jax.numpy as jnp

from repro.configs.fmm2d import fmm_config
from repro.core import direct_potential, rel_error_inf
from repro.solver import FmmSolver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--p", type=int, default=17)
    ap.add_argument("--dist", default="normal",
                    choices=["uniform", "normal", "layer"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "pallas"])
    ap.add_argument("--batch", type=int, default=4,
                    help="problems per apply_batched call (0 skips the "
                         "batched-serving section)")
    args = ap.parse_args()

    from repro.data.synthetic import particles
    z, q = particles(args.dist, args.n, seed=0)
    z, q = jnp.asarray(z), jnp.asarray(q)
    cfg = fmm_config(args.n, p=args.p, dtype="f64")
    print(f"[quickstart] N={args.n} ({args.dist}), p={args.p}, "
          f"levels={cfg.nlevels} ({4**cfg.nlevels} leaf boxes)")

    # tune() fits the padded-list caps to this workload (overflow-free,
    # shrunk padding); build() caches the compiled plan per config.
    solver = FmmSolver.build(cfg, args.backend).tune(z, q)
    print(f"[quickstart] tuned caps: strong={solver.cfg.strong_cap} "
          f"weak={solver.cfg.weak_cap} "
          f"(from {cfg.strong_cap}/{cfg.weak_cap})")

    t0 = time.perf_counter()
    phi = solver.apply(z, q)
    phi.block_until_ready()
    t_compile = time.perf_counter() - t0

    t0 = time.perf_counter()
    phi = solver.apply(z, q)
    phi.block_until_ready()
    t_run = time.perf_counter() - t0
    print(f"[quickstart] fmm: {t_run*1e3:.0f} ms/eval "
          f"(+{t_compile - t_run:.1f} s compile)")

    # spot-check 512 points against O(N^2) truth
    idx = np.random.default_rng(0).choice(args.n, 512, replace=False)
    ref = direct_potential(jnp.asarray(np.asarray(z)[idx]), z, q)
    err = rel_error_inf(np.asarray(phi)[idx], np.asarray(ref))
    print(f"[quickstart] rel err vs direct (512-pt sample): {err:.2e}")
    assert err < 1e-4, "accuracy regression"

    if args.batch > 0:
        # batched serving: build once, evaluate B independent problems
        # per call. The solver reports which backend the batched entry
        # point ACTUALLY runs — on the pallas backend the custom
        # batching rules keep the batch on batch-major kernel grids
        # (one fused launch per phase for all B problems).
        B = args.batch
        zb = jnp.stack([z] + [jnp.asarray(particles(args.dist, args.n,
                                                    seed=s)[0])
                              for s in range(1, B)])
        qb = jnp.stack([q] + [jnp.asarray(particles(args.dist, args.n,
                                                    seed=s)[1])
                              for s in range(1, B)])
        # the batch shares ONE cap budget: tune it on the (B, N) sample
        # (sized to the worst row), then serve with the batch-wide
        # overflow guard — an overflowing member raises instead of
        # silently returning truncated potentials.
        solver = solver.tune(zb, qb, tiles=False)
        phib = solver.apply_batched_checked(zb, qb)
        phib.block_until_ready()
        t0 = time.perf_counter()
        phib = solver.apply_batched(zb, qb)
        phib.block_until_ready()
        t_b = time.perf_counter() - t0
        print(f"[quickstart] batched: {B} problems/call, "
              f"{t_b*1e3:.0f} ms/call ({t_b/B*1e3:.0f} ms/problem), "
              f"dispatched={solver.dispatched['apply_batched']}")
        assert np.allclose(np.asarray(phib[0]), np.asarray(phi),
                           rtol=1e-6, atol=1e-6), "batched row 0 != apply"
    print("[quickstart] OK")


if __name__ == "__main__":
    main()

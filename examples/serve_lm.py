"""Batched serving example: prefill a batch of prompts, then greedy-decode
continuations through the same decode_step the dry-run lowers at 32k/500k.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b --gen 48
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main

if __name__ == "__main__":
    main()

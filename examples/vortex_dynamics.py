"""End-to-end driver: 2D point-vortex dynamics with FMM velocity evaluation
— the application domain the paper's code was built for (vortex methods;
Goude's wind-turbine wake simulations).

Each RK2 step evaluates the induced velocity field

    u - i v = (1 / 2*pi*i) * sum_j G_j / (z - z_j)

via the adaptive FMM (the paper's eq. (5.1) summation), advects the
vortices, and tracks the flow invariants (circulation and linear impulse
sum G_j z_j are conserved exactly by point-vortex dynamics, so their drift
measures integration+FMM error).

    PYTHONPATH=src python examples/vortex_dynamics.py --n 20000 --steps 20
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax.numpy as jnp

from repro.configs.fmm2d import fmm_config
from repro.solver import FmmSolver


def velocity(z, gamma, guard):
    """u + iv at each vortex (harmonic-kernel FMM, Biot-Savart in 2D).

    Splits the evaluation at the topology/evaluation seam
    (``refresh_guarded`` + ``apply_plan``): the guarded refresh reads
    the plan's cap margins (one host read, no extra builds) and — when
    advection drifts the layout past the t=0-tuned caps — re-plans at
    escalated caps instead of dropping interactions or dying mid-run.
    Returns (velocity, GuardReport)."""
    plan, report = guard.refresh_guarded(z, gamma.astype(z.dtype))
    phi = guard.apply_plan(plan)
    # phi_i = sum_j G_j/(z_j - z_i);  u - iv = phi/(2 pi i) -> conj
    return jnp.conj(phi / (2j * jnp.pi)), report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dt", type=float, default=2e-4)
    ap.add_argument("--p", type=int, default=12)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    n2 = args.n // 2
    # two counter-rotating Lamb-like clusters -> a translating vortex pair
    z0 = np.concatenate([
        0.35 + 0.5j + 0.08 * (rng.normal(size=n2) + 1j * rng.normal(size=n2)),
        0.65 + 0.5j + 0.08 * (rng.normal(size=args.n - n2)
                              + 1j * rng.normal(size=args.n - n2)),
    ])
    gamma = np.concatenate([np.full(n2, 1.0 / n2),
                            np.full(args.n - n2, -1.0 / (args.n - n2))])
    z = jnp.asarray(z0)
    g = jnp.asarray(gamma + 0j)
    cfg = fmm_config(args.n, p=args.p)
    # tune once on the initial layout; the caps keep head-room (margin)
    # for the advected positions so every step stays on the jit path
    solver = FmmSolver.build(cfg, "auto").tune(z, g, margin=1.5)
    # guarded refresh: every step reads the health margins; cap drift
    # re-plans through the escalation lattice instead of aborting
    guard = solver.guarded(max_cap_doublings=3)
    print(f"[vortex] N={args.n} vortices, {args.steps} RK2 steps, "
          f"p={args.p}, levels={cfg.nlevels}, "
          f"caps={guard.cfg.strong_cap}/{guard.cfg.weak_cap}")

    imp0 = complex(np.sum(gamma * z0))
    t0 = time.perf_counter()
    replans = 0
    for s in range(args.steps):
        u1, rep1 = velocity(z, g, guard)
        zm = z + 0.5 * args.dt * u1              # RK2 midpoint
        u2, rep2 = velocity(zm, g, guard)
        z = z + args.dt * u2
        replans += rep1.retries + rep2.retries
        if rep1.retries or rep2.retries:
            print(f"[vortex] step {s:3d}  re-planned: "
                  f"{(rep2 if rep2.retries else rep1).summary()}  "
                  f"caps now {guard.cfg.strong_cap}/{guard.cfg.weak_cap}")
        if s % 5 == 0 or s == args.steps - 1:
            imp = complex(np.sum(gamma * np.asarray(z)))
            drift = abs(imp - imp0) / max(abs(imp0), 1e-12)
            print(f"[vortex] step {s:3d}  impulse drift {drift:.2e}  "
                  f"replans {replans}  "
                  f"({(time.perf_counter()-t0)/(s+1):.2f} s/step avg)")
    assert guard.trace_counts["build"] == 1 or replans > 0, \
        "refresh re-traced mid-run without a cap re-plan"
    sep = abs(np.mean(np.asarray(z)[:n2]) - np.mean(np.asarray(z)[n2:]))
    print(f"[vortex] final cluster separation {sep:.3f} (pair translates, "
          f"separation ~const)")
    imp = complex(np.sum(gamma * np.asarray(z)))
    drift = abs(imp - imp0) / max(abs(imp0), 1e-12)
    assert drift < 1e-2, f"impulse drift {drift} too large"
    print("[vortex] OK — invariants preserved")


if __name__ == "__main__":
    main()

"""End-to-end LM training driver on the shared runtime: a GPT-style model
on the synthetic modular-arithmetic stream, with async checkpointing,
straggler monitoring and deterministic restart.

Default is CPU-sized (~10M params, 300 steps, loss visibly drops);
--preset 100m trains the ~100M-param config (same code path; give it a
real accelerator or patience).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--preset 10m]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step
from repro.data.synthetic import DataConfig, lm_batch
from repro.launch.runtime import StragglerMonitor, train_loop
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import OptConfig, init_opt_state

PRESETS = {
    "10m": dict(n_layers=4, d_model=256, n_heads=4, n_kv=2, d_ff=1024,
                vocab=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv=4, d_ff=3072,
                 vocab=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="runs/train_lm")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"gpt-{args.preset}", tie_embeddings=True,
                      param_dtype="float32", compute_dtype="float32",
                      attn_chunk=128, loss_chunk=64, remat="dots",
                      **PRESETS[args.preset])
    oc = OptConfig(name="adamw", lr=args.lr, warmup=20,
                   total_steps=args.steps, weight_decay=0.01)
    dc = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=0)

    params = lm.make_params(cfg, 0)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params")

    state = {"params": params, "opt": init_opt_state(params, oc),
             "step": jnp.zeros((), jnp.int32)}
    cm = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if latest_step(args.ckpt_dir) is not None:
        state, start = cm.restore_latest()
        state["step"] = jnp.asarray(state["step"])
        print(f"[train_lm] resumed from step {start}")

    step_jit = jax.jit(make_train_step(cfg, oc), donate_argnums=(0,))
    state, summary = train_loop(
        lambda s, b, i: step_jit(s, b),
        state, lambda s: lm_batch(dc, s), start_step=start,
        num_steps=args.steps, ckpt_manager=cm, ckpt_every=100,
        monitor=StragglerMonitor(), log_every=20)

    losses = summary["losses"]
    print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps (median {summary['median_step_time']*1e3:.0f}"
          f" ms/step)")
    assert losses[-1] < losses[0] - 0.5, "no learning progress"
    print("[train_lm] OK")


if __name__ == "__main__":
    main()

"""End-to-end serving demo: ragged, partially-poisoned traffic through
the serving plane (DESIGN.md §10).

Generates a log-normal request stream (every request a different N, a
configurable fraction poisoned), warms the plane's shape classes, and
serves wave after wave — printing a `ServeReport` line per request and
the plane's cumulative stats (per-bucket cache traffic, straggler
median, deadline misses) at the end. Nothing a request can contain
crashes the plane: it either returns a trustworthy phi or a typed
rejection.

    PYTHONPATH=src python examples/serve_traffic.py --num 24 \
        [--poison 0.2] [--deadline 30] [--median-n 128]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.data.synthetic import ragged_requests
from repro.serve import BucketLattice, Request, ServePlane


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--num", type=int, default=24)
    ap.add_argument("--poison", type=float, default=0.2)
    ap.add_argument("--median-n", type=int, default=128)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline budget in seconds")
    ap.add_argument("--waves", type=int, default=2)
    args = ap.parse_args()

    lattice = BucketLattice.geometric(64, 1024)
    plane = ServePlane(lattice, max_batch=4, direct_max=4096,
                       default_deadline_s=args.deadline)
    print(f"lattice: {lattice.sizes}; warming shape classes ...")
    t0 = time.perf_counter()
    plane.warm(batches=(1, 4))
    print(f"warmed {len(plane.cache)} executables "
          f"in {time.perf_counter() - t0:.1f}s")

    for wave in range(args.waves):
        reqs = [Request(z, q) for _, z, q, _ in
                ragged_requests(args.num, seed=wave,
                                median_n=args.median_n, sigma=0.8,
                                n_max=2048, poison_rate=args.poison)]
        t0 = time.perf_counter()
        results = plane.serve(reqs)
        dt = time.perf_counter() - t0
        print(f"\nwave {wave}: {len(reqs)} requests in {dt:.2f}s "
              f"({len(reqs) / dt:.1f} req/s)")
        for phi, report in results:
            print(" ", report.summary())

    stats = plane.stats()
    print("\ncumulative:",
          {k: stats[k] for k in ("requests", "ok", "recovered",
                                 "degraded", "rejected", "dispatches",
                                 "slow_dispatches", "deadline_misses")})
    print("cache (per bucket):",
          {b: "hits={hits} misses={misses} evictions={evictions}".format(**s)
           for b, s in stats["cache"].items()})
    med = stats["dispatch_median_s"]
    if np.isfinite(med):
        print(f"dispatch median: {med * 1e3:.1f}ms")


if __name__ == "__main__":
    main()

"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, all per chip per step:

  compute_s    = analytic model FLOPs / peak        (XLA's cost_analysis
                 counts while bodies once — measured — so FLOPs come from
                 the standard analytic model: 6*N_active*T (+attention
                 quadratic term, + recurrent-mixer terms); this is also
                 the MFU numerator, so fraction = compute/max(terms))
  memory_s     = analytic HBM bytes / HBM bandwidth (params/grads/optimizer
                 traffic + KV cache + activation-working-set model; the
                 measured temp_size is reported alongside but overstates
                 bf16 models on the CPU backend, which float-normalizes
                 bf16 dots to f32 and hoists the converts)
  collective_s = HLO-measured collective bytes (trip-count weighted) / ICI

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

HW = {"peak": 197e12, "hbm": 819e9, "ici": 50e9, "hbm_cap": 16 * 1024**3}


def model_flops_per_chip(cfg, shape, kind, n_chips):
    """Useful model FLOPs per chip (the MFU numerator): 6ND train / 2ND
    forward + the attention context term + recurrent-mixer terms."""
    S, B = shape.seq, shape.global_batch
    tokens = B * (S if kind != "decode" else 1)
    n_act = cfg.active_param_count()
    L = cfg.n_layers
    L_attn = int(round(cfg.attn_fraction * L))
    H, dh = cfg.n_heads, cfg.d_head
    d = cfg.d_model
    bwd = 3 if kind == "train" else 1

    flops = 2 * n_act * tokens * bwd
    ctx = S
    att = 4 * tokens * ctx * H * dh * L_attn \
        * (0.5 if kind != "decode" else 1.0) * bwd
    L_mamba = sum(m == "mamba" for m, _ in cfg.group) * cfg.n_groups
    L_rwkv = sum(m == "rwkv" for m, _ in cfg.group) * cfg.n_groups
    di = cfg.mamba_expand * d
    rec = tokens * (L_mamba * 10 * di * cfg.d_state
                    + L_rwkv * 6 * d * cfg.rwkv_head_size) * bwd
    return (flops + att + rec) / n_chips


def compute_overhead_factor(cfg, kind, tp: int = 16):
    """Non-useful compute multipliers, derived from config knobs:

      remat       "full" re-runs the forward in the backward (+1 of 3
                  passes -> 4/3), "dots" saves matmul outputs (~1.05)
      MoE         capacity-factor padding runs cf x expert flops
      TP padding  head counts not divisible by TP pad to the next multiple
    """
    f = 1.0
    if kind == "train":
        f *= {"full": 4.0 / 3.0, "dots": 1.05, "none": 1.0}[cfg.remat]
    if cfg.n_experts:
        moe_share = 0.6  # expert flops share of total (dominant for MoE)
        f *= (1 - moe_share) + moe_share * cfg.capacity_factor
    if cfg.n_heads % tp:
        pad = (-(-cfg.n_heads // tp) * tp) / cfg.n_heads
        attn_share = 0.25
        f *= (1 - attn_share) + attn_share * pad
    return f


def analytic_hbm_per_chip(cfg, shape, kind, n_chips, opt_name, num_micro=1):
    """Whole-step HBM traffic / chips (documented component model)."""
    S, B = shape.seq, shape.global_batch
    n_tot = cfg.param_count()
    d = cfg.d_model
    L = cfg.n_layers
    p_shard = 2 * n_tot / n_chips                    # bf16 params per chip

    if kind == "train":
        tokens_chip = B * S / n_chips
        # weights: fwd + bwd + remat-recompute reads per microbatch
        w = 3 * num_micro * p_shard
        # grads: f32 accumulate (read+write per micro) + optimizer read
        g = (2 * num_micro + 1) * 4 * n_tot / n_chips
        # optimizer state read+write (adamw: m,v f32; adafactor: ~m bf16)
        o = (16 if opt_name == "adamw" else 5) * n_tot / n_chips
        # activations: ~14 residual-sized tensors per layer fwd+bwd, bf16
        a = 28 * tokens_chip * d * L * 2
        return w + g + o + a
    if kind == "prefill":
        tokens_chip = B * S / n_chips
        kv_write = 2 * B * S * cfg.n_kv * cfg.d_head * 2 * \
            int(round(cfg.attn_fraction * L)) / n_chips
        return p_shard + 14 * tokens_chip * d * L * 2 + kv_write
    # decode
    L_attn = int(round(cfg.attn_fraction * L))
    cache = 2 * B * S * cfg.n_kv * cfg.d_head * 2 * L_attn / n_chips
    return p_shard + cache


def load_cells(art_dir: str = "artifacts/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(rec, cfg=None, shape=None):
    """Compute the table row for one artifact record."""
    from repro.configs import SHAPES, get_config, get_opt

    if rec.get("status") != "ok":
        return None
    if rec["arch"] == "fmm2d":
        terms = rec["roofline_terms_s"]
        dom = max(terms, key=terms.get)
        frac = terms["compute_s"] / max(max(terms.values()), 1e-30)
        return {**rec, "terms": terms, "dominant": dom, "fraction": frac,
                "hbm_analytic": rec.get("hbm_used", 0)}
    cfg = cfg or get_config(rec["arch"])
    shape = shape or SHAPES[rec["shape"]]
    kind = rec["kind"]
    n = rec["n_chips"]
    oc = get_opt(rec["arch"])
    num_micro = max(1, (shape.global_batch // (n // 16 if n > 256 else 16))
                    // max(1, 8192 // shape.seq)) if kind == "train" else 1
    useful = model_flops_per_chip(cfg, shape, kind, n)
    overhead = compute_overhead_factor(cfg, kind)
    hbm = analytic_hbm_per_chip(cfg, shape, kind, n, oc.name, num_micro)
    coll = rec["collectives"].get("total", 0.0) / n
    terms = {
        "compute_s": useful * overhead / HW["peak"],
        "memory_s": hbm / HW["hbm"],
        "collective_s": coll / HW["ici"],
    }
    dom = max(terms, key=terms.get)
    # roofline fraction == achievable MFU upper bound: useful-compute time
    # over the critical-path term (ideal compute/comm overlap assumed)
    frac = (useful / HW["peak"]) / max(max(terms.values()), 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": kind, "terms": terms, "dominant": dom, "fraction": frac,
        "model_flops_per_chip": useful,
        "compute_overhead": overhead,
        "hbm_analytic": hbm, "measured": rec.get("memory", {}),
        "collective_bytes_per_chip": coll,
    }


def run(art_dir: str = "artifacts/dryrun"):
    rows = []
    for rec in load_cells(art_dir):
        if rec.get("status") == "skipped":
            rows.append((f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
                         0.0, "SKIPPED(" + rec["reason"][:40] + ")"))
            continue
        if rec.get("status") == "failed":
            rows.append((f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
                         0.0, "FAILED " + rec.get("error", "")[:60]))
            continue
        r = roofline_row(rec)
        t = r["terms"]
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            max(t.values()) * 1e6,
            f"dom={r['dominant'][:-2]} frac={r['fraction']:.3f} "
            f"c={t['compute_s']:.2e} m={t['memory_s']:.2e} "
            f"x={t['collective_s']:.2e}",
        ))
    return rows

"""Benchmark harness — one module per paper table/figure plus the
time-stepping refresh benchmark and the kernel-tile sweep. Prints
``name,us_per_call,derived`` CSV (see README) and writes a
machine-readable ``BENCH_<rev>.json`` next to it (per-row times +
config) so CI can archive the perf trajectory run over run.

    PYTHONPATH=src python -m benchmarks.run [--only table5_1 fig5_5 ...]
    PYTHONPATH=src python -m benchmarks.run --quick   (CI-sized inputs)
    PYTHONPATH=src python -m benchmarks.run --json out.json
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
import traceback


def _git_rev() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              check=True).stdout.strip()
    except Exception:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="smaller N (CI-friendly)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="output path for the machine-readable record "
                         "(default: BENCH_<rev>.json)")
    args = ap.parse_args()

    from . import (accuracy, batched, fig5_2, fig5_3, fig5_5, fig5_8,
                   fmm_phases, guarded, kernel_tiles, serving, table5_1,
                   timestep)

    quick_kwargs = {
        "table5_1": {"n": 45 * 256},
        "fmm_phases": {"n": 45 * 256},
        "fig5_2": {"n": 1 << 13},
        "fig5_3": {"n": 1 << 12},
        "fig5_5": {},
        "fig5_8": {"n": 1 << 13},
        "accuracy": {"n": 2048},
        "batched": {"n": 1024, "batch": 4},
        "timestep": {"n": 2048, "steps": 3},
        "kernel_tiles": {"n": 1024, "repeats": 1},
        "guarded": {"n": 2048, "repeats": 2},
        "serving": {"n": 512, "num": 10, "median_n": 48},
    }
    benches = {
        "table5_1": table5_1.run,
        "fmm_phases": fmm_phases.run,
        "fig5_2": fig5_2.run,
        "fig5_3": fig5_3.run,
        "fig5_5": fig5_5.run,
        "fig5_8": fig5_8.run,
        "accuracy": accuracy.run,
        "batched": batched.run,
        "timestep": timestep.run,
        "kernel_tiles": kernel_tiles.run,
        "guarded": guarded.run,
        "serving": serving.run,
    }
    names = args.only or list(benches)
    print("name,us_per_call,derived")
    failed = []
    rows = []
    for name in names:
        try:
            kwargs = quick_kwargs.get(name, {}) if args.quick else {}
            for row in benches[name](**kwargs):
                label, us, derived = row
                print(f"{label},{us:.1f},{derived}")
                rows.append({"bench": name, "name": label,
                             "us_per_call": us, "derived": derived})
            sys.stdout.flush()
        except Exception:
            failed.append(name)
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)

    import jax
    rev = _git_rev()
    record = {
        "rev": rev,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "platform": jax.default_backend(),
        "jax": jax.__version__,
        "quick": args.quick,
        "failed": failed,
        "results": rows,
    }
    path = args.json or f"BENCH_{rev}.json"
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {path}", file=sys.stderr)

    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()

"""Benchmark harness — one module per paper table/figure plus the roofline
reader. Prints ``name,us_per_call,derived`` CSV (see README).

    PYTHONPATH=src python -m benchmarks.run [--only table5_1 fig5_5 ...]
    PYTHONPATH=src python -m benchmarks.run --quick   (CI-sized inputs)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="smaller N (CI-friendly)")
    args = ap.parse_args()

    from . import (accuracy, batched, fig5_2, fig5_3, fig5_5, fig5_8,
                   roofline, table5_1)

    quick_kwargs = {
        "table5_1": {"n": 45 * 256},
        "fig5_2": {"n": 1 << 13},
        "fig5_3": {"n": 1 << 12},
        "fig5_5": {},
        "fig5_8": {"n": 1 << 13},
        "accuracy": {"n": 2048},
        "batched": {"n": 1024, "batch": 4},
        "roofline": {},
    }
    benches = {
        "table5_1": table5_1.run,
        "fig5_2": fig5_2.run,
        "fig5_3": fig5_3.run,
        "fig5_5": fig5_5.run,
        "fig5_8": fig5_8.run,
        "accuracy": accuracy.run,
        "batched": batched.run,
        "roofline": roofline.run,
    }
    names = args.only or list(benches)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            kwargs = quick_kwargs.get(name, {}) if args.quick else {}
            for row in benches[name](**kwargs):
                label, us, derived = row
                print(f"{label},{us:.1f},{derived}")
            sys.stdout.flush()
        except Exception:
            failed.append(name)
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()

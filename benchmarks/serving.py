"""Ragged-traffic serving throughput: bucketed plane vs naive loop.

The headline number of the serving plane (DESIGN.md §10): sustained
requests/sec on a synthetic ragged workload (log-normal N — every
request a fresh size) served two ways:

  naive      one ``FmmSolver.build(...).apply`` per request at the
             request's exact N — every fresh size is a fresh XLA
             program, so sustained ragged traffic pays a compile per
             request, forever (the solver LRU only helps when an exact
             N recurs)
  bucketed   ``ServePlane``: round N up to a geometric bucket lattice,
             pad with zero charges, group into batched guarded
             dispatches through the keyed executable cache — a fixed
             handful of programs serves every size

Both systems first process a settling wave (the plane additionally
warms its batch-width classes — its designed warm-up precompile), then
the *measured* wave arrives with sizes neither has seen. That is the
sustained regime: the plane serves it entirely from cache hits; the
naive loop compiles per request, which is exactly the cost the
bucketing design amortizes away.

Rows (``serving/`` prefix; ``*_cold`` rows are compile-dominated and
skipped by ``scripts/bench_compare.py``):
  serving/naive_per_request_cold     naive loop on the fresh wave
  serving/bucketed_per_request      plane on the fresh wave (gated)
  serving/admission_reject          typed-rejection latency (gated)
  serving/poisoned_wave_per_request mixed wave, 25% poison (gated)

Inline gate (ISSUE 10 acceptance): bucketed sustained throughput must
be >= 5x the naive loop's.
"""
from __future__ import annotations

import time

import jax
import numpy as np
import jax.numpy as jnp

from repro.data.synthetic import ragged_requests
from repro.serve import BucketLattice, Request, ServePlane
from repro.solver import FmmSolver

#: acceptance gate: sustained bucketed requests/sec over naive
SPEEDUP_GATE = 5.0


def _wave(num, seed, median_n, n_max, poison_rate=0.0):
    return [(Request(z, q), kind) for _, z, q, kind in
            ragged_requests(num, seed=seed, median_n=median_n, sigma=0.7,
                            n_max=n_max, poison_rate=poison_rate)]


def _naive_loop(wave, p, backend):
    """The baseline: each request solved at its exact N (compile per
    fresh size — what serving ragged traffic without buckets costs)."""
    from repro.configs.fmm2d import fmm_config

    out = []
    for req, _ in wave:
        n = req.z.size
        solver = FmmSolver.build(fmm_config(n, p=p, dtype="f64"), backend)
        out.append(np.asarray(solver.apply(jnp.asarray(req.z),
                                           jnp.asarray(req.q))))
    jax.block_until_ready(out[-1])
    return out


def run(n: int = 45 * 256, num: int = 24, p: int = 10,
        backend: str = "auto", median_n: int = 256):
    """Benchmark-harness entry. ``n`` bounds the lattice (and the
    workload's n_max at half of it); ``num`` is the measured wave size."""
    from repro.serve.cache import default_cfg_factory

    lattice = BucketLattice.geometric(32, n)
    n_max = max(64, n // 2)

    def cfg_factory(size):
        return default_cfg_factory(size, p=p, dtype="f64")

    plane = ServePlane(lattice, backend=backend, cfg_factory=cfg_factory,
                       max_batch=4, direct_max=n)
    FmmSolver.cache_clear()

    # settle: both systems see one wave; the plane also warms its batch
    # widths (the designed warm-up precompile, repro.serve.cache)
    settle = _wave(num, seed=100, median_n=median_n, n_max=n_max)
    t0 = time.perf_counter()
    plane.serve([r for r, _ in settle])
    # warm every shape class the workload can reach (the designed
    # warm-up precompile): in the sustained regime the plane serves
    # fresh sizes from cache hits while the naive loop compiles per size
    top = lattice.bucket_for(n_max) or lattice.max_size
    buckets = [s for s in lattice.sizes if s <= top]
    plane.cache.warm_all(buckets, (1, 2, 4))
    plane_settle = time.perf_counter() - t0
    t0 = time.perf_counter()
    _naive_loop(settle, p, backend)
    naive_settle = time.perf_counter() - t0

    # measure: a wave of sizes neither system has seen (fresh seeds)
    wave = _wave(num, seed=200, median_n=median_n, n_max=n_max)
    t0 = time.perf_counter()
    results = plane.serve([r for r, _ in wave])
    bucketed_t = time.perf_counter() - t0
    assert all(rep.status in ("ok", "recovered", "degraded")
               for _, rep in results), \
        [rep.summary() for _, rep in results if rep.status == "rejected"]

    t0 = time.perf_counter()
    _naive_loop(wave, p, backend)
    naive_t = time.perf_counter() - t0

    speedup = naive_t / bucketed_t
    assert speedup >= SPEEDUP_GATE, (
        f"bucketed serving sustains only {speedup:.1f}x the naive "
        f"per-request loop (gate {SPEEDUP_GATE:.0f}x): "
        f"naive {naive_t:.2f}s vs bucketed {bucketed_t:.2f}s for "
        f"{num} requests")

    # typed-rejection latency: admission control is pure host work
    bad = Request(np.full(64, np.nan + 0j), np.ones(64) + 0j)
    plane.submit(bad.z, bad.q)      # warm the path
    t0 = time.perf_counter()
    reject_reps = 20
    for _ in range(reject_reps):
        _, rep = plane.submit(bad.z, bad.q)
    reject_t = (time.perf_counter() - t0) / reject_reps
    assert rep.status == "rejected" and rep.error == "NonFiniteInputError"

    # mixed wave with poison: the robustness steady state — rejects ride
    # along without stalling the clean traffic (sizes seen before, so
    # this is warm dispatch + admission screening)
    poisoned = _wave(num, seed=200, median_n=median_n, n_max=n_max,
                     poison_rate=0.25)
    t0 = time.perf_counter()
    presults = plane.serve([r for r, _ in poisoned])
    poisoned_t = time.perf_counter() - t0
    served = sum(r.status != "rejected" for _, r in presults)
    rejected = num - served

    return [
        ("serving/naive_per_request_cold", naive_t / num * 1e6,
         f"N~lognormal(med={median_n}) num={num} compile-per-size"),
        ("serving/bucketed_per_request", bucketed_t / num * 1e6,
         f"speedup={speedup:.1f}x (gate {SPEEDUP_GATE:.0f}x) "
         f"buckets={len(buckets)}"),
        ("serving/settle_cold", plane_settle / num * 1e6,
         f"first-wave cost incl. warmup (naive settle "
         f"{naive_settle / num * 1e6:.0f}us/req)"),
        ("serving/admission_reject", reject_t * 1e6,
         "typed NonFiniteInputError, host-only"),
        ("serving/poisoned_wave_per_request", poisoned_t / num * 1e6,
         f"poison_rate=0.25: {served} served, {rejected} rejected, "
         "zero unhandled"),
    ]

"""Kernel-level tiling sweep: time the P2P and M2L Pallas kernels across
``tile_boxes`` (and ``stage_width``) to document the multi-box tiling win
and seed the autotuner defaults (``repro.solver.autotune.tune_tiles``).

On a TPU this measures the compiled kernels; off-TPU the kernels run in
interpret mode and every row is annotated ``interpret=True`` — those
numbers time the Pallas interpreter, not the hardware, and exist only so
the harness (shapes, sweep, CSV/JSON plumbing) is exercised in CI.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import fmm_build, leaf_particle_index
from repro.core.fmm import effective_radii, upward
from repro.data.synthetic import particles
from repro.kernels import m2l_fused_apply, p2p_apply
from repro.kernels.common import default_interpret

TILES = (1, 2, 4, 8, 16)
STAGES = (1, 2)


def _best_of(fn, repeats=3):
    jax.block_until_ready(fn())            # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run(n: int = 1 << 14, p: int = 8, repeats: int = 3):
    import dataclasses

    from repro.configs.fmm2d import fmm_config

    base = fmm_config(n, p=p, dtype="f32")
    z, q = particles("normal", n, 7)
    z, q = jnp.asarray(z), jnp.asarray(q)
    interp = default_interpret()
    note = f"interpret={interp}"

    plan = fmm_build(z, q, base)
    idx = leaf_particle_index(base)
    rho = effective_radii(plan.tree, base)
    mult = upward(plan.tree, base, rho)

    for tb in TILES:
        if tb > base.nboxes:
            continue
        for sw in STAGES:
            cfg = dataclasses.replace(base, tile_boxes=tb, stage_width=sw)

            def p2p():
                return p2p_apply(plan.tree, plan.conn, cfg, idx)

            t = _best_of(p2p, repeats)
            yield (f"kernel_tiles.p2p.tb{tb}.sw{sw}", t * 1e6,
                   f"n={n} {note}")

            def m2l():
                return m2l_fused_apply(mult, plan.conn.weak,
                                       plan.tree.centers, cfg, rho)

            t = _best_of(m2l, repeats)
            yield (f"kernel_tiles.m2l.tb{tb}.sw{sw}", t * 1e6,
                   f"n={n} levels={base.nlevels} {note}")

"""Accuracy ledger: TOL (eq. 5.3) vs p — validates the paper's
p ~ log TOL / log theta calibration (p=17 -> ~1e-6 at theta=1/2)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import FmmConfig, direct_potential, rel_error_inf
from repro.data.synthetic import particles
from repro.solver import FmmSolver


def run(n: int = 4096):
    z, q = particles("uniform", n, 0)
    z, q = jnp.asarray(z), jnp.asarray(q)
    ref = direct_potential(z, z, q)
    rows = []
    for p in (5, 9, 13, 17, 21):
        cfg = FmmConfig(n=n, nlevels=3, p=p, dtype="f64")
        solver = FmmSolver.build(cfg, "reference")
        err = rel_error_inf(np.asarray(solver.apply(z, q)),
                            np.asarray(ref))
        pred = (1 / 3) ** p  # contraction theta/(1+theta) per term
        rows.append((f"accuracy/p={p}", 0.0,
                     f"TOL={err:.2e} theory~{pred:.1e}"))
    return rows

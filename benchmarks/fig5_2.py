"""Fig 5.2 reproduction: total evaluation time vs N_d (sources per leaf box).

The paper finds a broad optimum near N_d=45 on the GPU / 35 on the CPU: few
particles per box shifts work into M2L/tree overhead, many per box into the
quadratic P2P. We sweep the tree depth at fixed N, which steps N_d by 4x."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import FmmConfig
from repro.data.synthetic import particles
from .fmm_phases import phase_times


def run(n: int = 1 << 14, p: int = 17):
    z, q = particles("uniform", n, 0)
    rows = []
    best = (None, float("inf"))
    for levels in (3, 4, 5, 6):
        nd = n / 4**levels
        if nd < 2:
            continue
        cfg = FmmConfig(n=n, nlevels=levels, p=p)
        times = phase_times(jnp.asarray(z), jnp.asarray(q), cfg, repeats=2)
        total = sum(times.values())
        rows.append((f"fig5_2/Nd={nd:.0f}", total * 1e6,
                     f"p2p={100*times['p2p']/total:.0f}% "
                     f"m2l={100*times['m2l']/total:.0f}% "
                     f"sort={100*times['sort']/total:.0f}%"))
        if total < best[1]:
            best = (nd, total)
    rows.append(("fig5_2/optimum_Nd", best[1] * 1e6, f"Nd={best[0]:.0f}"))
    return rows

"""Fig 5.5/5.6 reproduction: FMM vs direct summation break-even point.

Paper: the GPU FMM overtakes direct summation at N ~ 3500 (p=17,
TOL~1e-6). We measure both on this backend and report the crossover."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import FmmConfig, direct_potential
from repro.core.config import num_levels_for
from repro.data.synthetic import particles
from repro.solver import FmmSolver


def _best(fn, *args, repeats=3):
    fn(*args).block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run(p: int = 17):
    rows = []
    crossover = None
    for logn in (9, 10, 11, 12, 13):
        n = 1 << logn
        z, q = particles("uniform", n, 0)
        z, q = jnp.asarray(z), jnp.asarray(q)
        lv = max(1, num_levels_for(n, 45))
        cfg = FmmConfig(n=n, nlevels=lv, p=p)
        solver = FmmSolver.build(cfg, "auto")
        t_fmm = _best(solver.apply, z, q)
        t_dir = _best(lambda a, b: direct_potential(a, b, b * 0 + q), z, z)
        rows.append((f"fig5_5/N={n}", t_fmm * 1e6,
                     f"direct={t_dir*1e6:.0f}us ratio={t_dir/t_fmm:.2f}"))
        if crossover is None and t_fmm < t_dir:
            crossover = n
    rows.append(("fig5_5/breakeven_N", 0.0,
                 f"N={crossover} (paper GPU: ~3500)"))
    return rows

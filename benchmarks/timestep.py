"""Time-stepping (plan-refresh) benchmark — the vortex-method scenario.

Holm, Engblom, Goude & Holmgren (arXiv:1311.1006) motivate the workload:
particles advect a little every step, so the tree + connectivity must be
rebuilt thousands of times under a *fixed* cap/tile budget. The cost
model this benchmark pins down:

  cold   first guarded refresh — trace + compile + build
  refresh steady-state per-step topology rebuild (the compiled
         single-sort build + batched connect; no re-trace), via
         ``refresh_guarded`` — the production time-stepping path now
         includes the per-step health read and re-plans on cap drift
  apply_plan steady-state evaluation on a refreshed plan
  step   refresh + apply_plan (one full time step's FMM work)

``run`` asserts refresh ≪ cold: a time-stepping loop must pay tracing
and compilation once, not per step — a regression here means the plan
cache or the refresh entry point started re-tracing.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import particles
from repro.solver import FmmSolver, GuardedSolver


def _best_of(fn, repeats):
    jax.block_until_ready(fn())          # warm-up: exclude trace+compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run(n: int = 45 * 256, p: int = 10, steps: int = 5,
        backend: str = "auto", repeats: int = 3):
    """Benchmark-harness entry: cold vs steady-state refresh timings."""
    from repro.configs.fmm2d import fmm_config

    z, q = particles("uniform", n, 0)
    z, q = jnp.asarray(z), jnp.asarray(q)
    cfg = fmm_config(n, p=p)
    FmmSolver.cache_clear()
    guard = GuardedSolver(cfg, backend)

    t0 = time.perf_counter()
    plan, _ = guard.refresh_guarded(z, q)
    jax.block_until_ready(plan.conn.overflow)
    cold = time.perf_counter() - t0
    solver = guard.solver     # possibly promoted past an escalation

    # advected positions: a small deterministic drift, re-clamped to the
    # unit square (per component — complex clip compares lexicographically)
    # so the tuned caps remain representative
    rng = np.random.default_rng(1)

    def drifted():
        zd = np.asarray(z) + 1e-3 * (rng.normal(size=n)
                                     + 1j * rng.normal(size=n))
        return jnp.asarray(np.clip(zd.real, 0, 1) + 1j * np.clip(zd.imag, 0, 1))

    drifts = [drifted() for _ in range(steps)]

    refresh = min(
        _best_of(
            lambda zi=zi: guard.refresh_guarded(zi, q)[0].conn.overflow,
            repeats)
        for zi in drifts)
    apply_plan = _best_of(lambda: guard.apply_plan(plan), repeats)
    step = _best_of(
        lambda: guard.apply_plan(guard.refresh_guarded(drifts[0], q)[0]),
        repeats)

    assert guard.trace_counts["build"] == 1, (
        f"refresh re-traced ({guard.trace_counts['build']}x): the "
        "time-stepping path must compile once")
    assert refresh * 2 < cold, (
        f"steady-state refresh ({refresh:.4f}s) not << cold build "
        f"({cold:.4f}s): compile cost is leaking into the per-step path")

    name = solver.dispatched["apply"]
    return [
        ("timestep/cold", cold * 1e6, f"backend={name} N={n}"),
        ("timestep/refresh", refresh * 1e6, name),
        ("timestep/apply_plan", apply_plan * 1e6, name),
        ("timestep/step", step * 1e6,
         f"refresh+apply_plan ratio={refresh / max(step, 1e-12):.2f}"),
    ]

"""Table 5.1 reproduction: time distribution of the FMM phases at the
calibrated N_d ~ 45 (paper: P2P 43%, sort 30%, M2L 11%, P2M 5%, L2P 2%,
connect 1% on the C2075; here: same structure measured on this backend)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.fmm2d import N_D, P_TERMS, fmm_config
from repro.data.synthetic import particles
from .fmm_phases import phase_times


def run(n: int = 45 * 512, p: int = P_TERMS, dist: str = "uniform"):
    z, q = particles(dist, n, 0)
    cfg = fmm_config(n, p=p)
    times = phase_times(jnp.asarray(z), jnp.asarray(q), cfg)
    # the fused "topology" entry re-measures sort + connect (it is the
    # refresh-path timing, reported by fmm_phases/timestep) — keep the
    # paper's per-phase rows and percentages free of double counting
    times.pop("topology", None)
    total = sum(times.values())
    rows = []
    for k, v in sorted(times.items(), key=lambda kv: -kv[1]):
        rows.append((f"table5_1/{k}", v * 1e6, f"{100*v/total:.1f}%"))
    rows.append(("table5_1/total", total * 1e6,
                 f"N={n} Nd~{N_D} p={p} levels={cfg.nlevels}"))
    return rows

"""Fig 5.3/5.4 reproduction: phase cost vs number of expansion terms p.

Paper: initialization/evaluation scale linearly in p, shift operators have
linear pre/post-scaling plus a quadratic core; the optimal N_d grows
~linearly with p (Fig 5.4)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import FmmConfig
from repro.data.synthetic import particles
from .fmm_phases import phase_times


def run(n: int = 1 << 14):
    z, q = particles("uniform", n, 0)
    rows = []
    for p in (5, 11, 17, 25):
        cfg = FmmConfig(n=n, nlevels=3, p=p)
        t = phase_times(jnp.asarray(z), jnp.asarray(q), cfg, repeats=2)
        rows.append((f"fig5_3/p={p}", sum(t.values()) * 1e6,
                     f"m2l={t['m2l']*1e6:.0f}us p2m={t['p2m']*1e6:.0f}us "
                     f"l2p={t['l2p']*1e6:.0f}us p2p={t['p2p']*1e6:.0f}us"))
    return rows

"""Guarded-execution overhead + recovery-latency benchmark.

Pins down the two costs of the robustness layer (DESIGN.md §9):

  steady state   ``apply_guarded`` on a healthy input vs plain ``apply``
                 — the in-graph health plane rides the same launch, so
                 the difference is one host read of a few scalars. The
                 acceptance gate (asserted here): <= 5% overhead.
  recovery       per-rung latency of an actual ladder walk under the
                 fault injectors — cold (first escalation pays the
                 neighbor plan's compile) vs warm (the ``FmmSolver``
                 LRU already holds the lattice, a recovery is detection
                 + plan switch), and the forced walk to the direct rung.

Rows (``guarded/`` prefix, gated in ``scripts/bench_compare.py``):
  guarded/apply            plain apply baseline
  guarded/apply_guarded    guarded steady state (the <= 5% gate)
  guarded/refresh_guarded  guarded plan refresh steady state
  guarded/recover_caps_cold   first cap escalation (includes compile)
  guarded/recover_caps_warm   escalation with a precompiled lattice
  guarded/recover_direct      full walk to the O(N^2) last resort
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data.synthetic import particles
from repro.solver import FmmSolver, GuardedSolver
from repro.testing import force_cap_overflow, truncate_interaction_lists

#: steady-state gate: relative bound + an absolute floor so sub-ms CPU
#: timings don't fail on host-read jitter
OVERHEAD_REL = 0.05
OVERHEAD_ABS = 2e-4


def _best_of(fn, repeats):
    jax.block_until_ready(fn())          # warm-up: exclude trace+compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _once(fn):
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def run(n: int = 45 * 256, p: int = 10, backend: str = "auto",
        repeats: int = 5):
    """Benchmark-harness entry: steady-state overhead + recovery rungs."""
    from repro.configs.fmm2d import fmm_config

    z, q = particles("uniform", n, 0)
    z, q = jnp.asarray(z), jnp.asarray(q)
    cfg = fmm_config(n, p=p)
    FmmSolver.cache_clear()

    solver = FmmSolver.build(cfg, backend)
    name = solver.dispatched["apply"]
    apply_t = _best_of(lambda: solver.apply(z, q), repeats)

    guard = GuardedSolver(cfg, backend)
    guarded_t = _best_of(lambda: guard.apply_guarded(z, q)[0], repeats)
    overhead = guarded_t / apply_t - 1.0
    assert guarded_t <= apply_t * (1.0 + OVERHEAD_REL) + OVERHEAD_ABS, (
        f"guarded steady state {guarded_t * 1e6:.0f}us exceeds the "
        f"{OVERHEAD_REL:.0%} overhead gate over apply "
        f"({apply_t * 1e6:.0f}us)")

    refresh_t = _best_of(
        lambda: guard.refresh_guarded(z, q)[0].conn.overflow, repeats)

    # recovery latency: drop enough that the fullest list class
    # overflows at the declared caps but fits after one doubling
    margins = solver.stats(z, q)["margins"]
    drop = min(min(margins.values()) + 4,
               min(cfg.strong_cap, cfg.weak_cap) - 1)

    with truncate_interaction_lists(drop=drop):
        g0 = GuardedSolver(cfg, backend, max_cap_doublings=2)
        t0 = time.perf_counter()
        _, cold_report = g0.apply_guarded(z, q)
        cold = time.perf_counter() - t0
        rungs = len(cold_report.attempts)

        def walk():
            # fresh guard each call: primary overflows, escalation hits
            # the already-compiled lattice neighbor (the LRU is warm)
            gi = GuardedSolver(cfg, backend, max_cap_doublings=2)
            return gi.apply_guarded(z, q)[0]

        warm = _best_of(walk, repeats)

    with force_cap_overflow(strong=1, weak=1):
        gd = GuardedSolver(cfg, backend, max_cap_doublings=1)
        jax.block_until_ready(gd.apply_guarded(z, q)[0])   # compile walk
        direct_walk = _once(
            lambda: GuardedSolver(cfg, backend,
                                  max_cap_doublings=1).apply_guarded(z, q)[0])

    return [
        ("guarded/apply", apply_t * 1e6, f"backend={name} N={n}"),
        ("guarded/apply_guarded", guarded_t * 1e6,
         f"overhead={overhead:+.1%} (gate {OVERHEAD_REL:.0%})"),
        ("guarded/refresh_guarded", refresh_t * 1e6, name),
        ("guarded/recover_caps_cold", cold * 1e6,
         f"drop={drop} includes neighbor-plan compile"),
        ("guarded/recover_caps_warm", warm * 1e6,
         f"rungs={rungs} lattice precompiled"),
        ("guarded/recover_direct", direct_walk * 1e6,
         "full walk to O(N^2)"),
    ]

"""Benchmark harness: one module per paper table/figure (Table 5.1,
Figs 5.2/5.3/5.5/5.8) + accuracy ledger + the time-stepping refresh benchmark."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax as _jax

_jax.config.update("jax_enable_x64", True)  # f64 FMM oracle paths

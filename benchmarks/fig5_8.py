"""Fig 5.8/5.9 reproduction: robustness of the asymmetric adaptivity under
non-uniform inputs. Paper: normal/layer distributions cost only modestly
more than uniform (the adaptive tree equidistributes particles), with the
increase concentrated in P2P."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.fmm2d import fmm_config
from repro.data.synthetic import particles
from .fmm_phases import phase_times


def run(n: int = 1 << 14, p: int = 17):
    rows = []
    base = None
    import dataclasses
    for dist in ("uniform", "normal", "layer"):
        z, q = particles(dist, n, 0)
        # non-uniform trees need deeper interaction lists (overflow-checked
        # caps; cf. fmm_potential_checked)
        cfg = dataclasses.replace(fmm_config(n, p=p), strong_cap=96,
                                  weak_cap=0)
        t = phase_times(jnp.asarray(z), jnp.asarray(q), cfg, repeats=2)
        total = sum(t.values())
        if base is None:
            base = total
        rows.append((f"fig5_8/{dist}", total * 1e6,
                     f"vs_uniform={total/base:.2f}x "
                     f"p2p_share={100*t['p2p']/total:.0f}%"))
    return rows

"""Batched multi-problem throughput — the serving shape.

B independent FMM problems of one ``FmmConfig`` evaluated in a single
``FmmSolver.apply_batched`` call (one XLA program with a batch axis) vs a
Python loop of single-problem ``apply`` calls. Because all adaptivity
lives in the contents of statically-shaped padded lists, the batch
dimension is free parallelism; on the pallas backend the custom batching
rules additionally fold the batch into the batch-major kernel grids —
one fused launch per phase for all B problems. This is the "millions of
users" path the solver front-end exists for.

Every row's ``derived`` field records ``dispatched=<backend>`` — what
``solver.dispatched["apply_batched"]`` reports the batched entry point
ACTUALLY ran — so timings cannot silently be attributed to the wrong
backend. Off-TPU the pallas kernels run in interpret mode (noise, not
kernel speed): timing a pallas-dispatched batched path there is refused
unless ``allow_interpret=True`` opts in (mirroring ``fmm_phases``), and
the opted-in rows carry an ``interpreted`` marker.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.fmm2d import fmm_config
from repro.data.synthetic import particles
from repro.kernels.common import default_interpret
from repro.solver import FmmSolver


def _best(fn, *args, repeats=3):
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run(n: int = 4096, batch: int = 8, p: int = 8, backend: str = "auto",
        allow_interpret: bool = False):
    cfg = fmm_config(n, p=p)
    zb = np.stack([np.asarray(particles("uniform", n, s)[0])
                   for s in range(batch)])
    qb = np.stack([np.asarray(particles("uniform", n, s)[1])
                   for s in range(batch)])
    zb, qb = jnp.asarray(zb), jnp.asarray(qb)

    solver = FmmSolver.build(cfg, backend).tune(zb, qb)
    dispatched = solver.dispatched["apply_batched"]
    interpreted = dispatched == "pallas" and default_interpret()
    if interpreted and not allow_interpret:
        raise RuntimeError(
            "refusing to time apply_batched dispatched to 'pallas' in "
            "interpret mode (off-TPU): interpreted timings measure the "
            "Pallas interpreter, not the batch-major kernels. Run on a "
            "TPU, use backend='reference', or pass allow_interpret=True "
            "to get annotated noise.")
    tag = f"dispatched={dispatched}" + (" interpreted" if interpreted
                                        else "")

    def looped(z, q):
        return [solver.apply(z[i], q[i]) for i in range(batch)]

    t_loop = _best(looped, zb, qb)
    t_batched = _best(solver.apply_batched, zb, qb)

    rows = [
        (f"batched/B={batch}_loop", t_loop * 1e6,
         f"problems_per_call=1 {tag}"),
        (f"batched/B={batch}_batched", t_batched * 1e6,
         f"problems_per_call={batch} speedup={t_loop / t_batched:.2f}x "
         f"{tag}"),
        (f"batched/B={batch}_caps", 0.0,
         f"tuned strong={solver.cfg.strong_cap} weak={solver.cfg.weak_cap} "
         f"{tag}"),
    ]
    return rows

"""Batched multi-problem throughput — the serving shape.

B independent FMM problems of one ``FmmConfig`` evaluated in a single
``FmmSolver.apply_batched`` call (one XLA program with a batch axis) vs a
Python loop of single-problem ``apply`` calls. Because all adaptivity
lives in the contents of statically-shaped padded lists, the batch
dimension is free parallelism; this is the "millions of users" path the
solver front-end exists for.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.fmm2d import fmm_config
from repro.data.synthetic import particles
from repro.solver import FmmSolver


def _best(fn, *args, repeats=3):
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run(n: int = 4096, batch: int = 8, p: int = 8):
    cfg = fmm_config(n, p=p)
    zb = np.stack([np.asarray(particles("uniform", n, s)[0])
                   for s in range(batch)])
    qb = np.stack([np.asarray(particles("uniform", n, s)[1])
                   for s in range(batch)])
    zb, qb = jnp.asarray(zb), jnp.asarray(qb)

    solver = FmmSolver.build(cfg, "reference").tune(zb, qb)

    def looped(z, q):
        return [solver.apply(z[i], q[i]) for i in range(batch)]

    t_loop = _best(looped, zb, qb)
    t_batched = _best(solver.apply_batched, zb, qb)

    rows = [
        (f"batched/B={batch}_loop", t_loop * 1e6, "problems_per_call=1"),
        (f"batched/B={batch}_batched", t_batched * 1e6,
         f"problems_per_call={batch} speedup={t_loop / t_batched:.2f}x"),
        (f"batched/B={batch}_caps", 0.0,
         f"tuned strong={solver.cfg.strong_cap} weak={solver.cfg.weak_cap}"),
    ]
    return rows

"""Per-phase FMM timing on the current backend (CPU here; the same jitted
callables run on TPU). Phases follow the paper's Table 5.1 naming.

``backend`` selects the hot-phase implementations (P2P, M2L, L2P, and
the topology phase's leaf classification) from the
``repro.solver.backends`` registry — "reference" times the core jnp
sweeps, "pallas" the TPU kernels. The whole topological phase (what
``FmmSolver.refresh`` re-runs per time step) is additionally timed as
the first-class ``topology`` entry (excluded from the total row, which
already counts sort + connect). Off-TPU the Pallas kernels run in
*interpret* mode — a correctness tool whose timings say nothing about
the compiled kernels — so timing the pallas backend there is refused
unless ``allow_interpret=True`` explicitly opts into the noise (the
returned dict then carries an ``"interpreted"`` marker key)."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core import (FmmConfig, build_connectivity, build_tree,
                        leaf_particle_index)
from repro.core import expansions as E
from repro.core import fmm as F
from repro.data.synthetic import particles
from repro.kernels.common import default_interpret
from repro.solver import get_backend


def _timed(fn, *args, repeats=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def phase_times(z, q, cfg: FmmConfig, repeats: int = 3,
                backend: str = "reference",
                allow_interpret: bool = False) -> dict[str, float]:
    """Seconds per phase (best of ``repeats`` post-compile)."""
    times: dict[str, float] = {}
    be = get_backend(backend, cfg)
    interpreted = be.name == "pallas" and default_interpret()
    if interpreted and not allow_interpret:
        raise RuntimeError(
            "refusing to time the pallas backend in interpret mode "
            "(off-TPU): interpreted timings measure the Pallas "
            "interpreter, not the kernels. Run on a TPU, use "
            "backend='reference', or pass allow_interpret=True to get "
            "annotated noise.")
    if interpreted:
        # annotation only: zero seconds so consumers that aggregate the
        # dict (sum of phase times, percentage rows) are unperturbed
        times["interpreted"] = 0.0

    build_j = jax.jit(functools.partial(build_tree, cfg=cfg))
    times["sort"], tree = _timed(build_j, z, q, repeats=repeats)

    conn_j = jax.jit(functools.partial(
        build_connectivity, cfg=cfg,
        leaf_classify_impl=be.topology_impls(cfg)["leaf_classify_impl"]))
    times["connect"], conn = _timed(conn_j, tree, repeats=repeats)

    # the whole topological phase as ONE compiled entry — what
    # FmmSolver.refresh runs per step of a time-stepping workload.
    # Excluded from the total: it re-measures sort + connect fused.
    topo_j = jax.jit(lambda z, q: F.fmm_build(
        z, q, cfg, **be.topology_impls(cfg)))
    times["topology"], _ = _timed(topo_j, z, q, repeats=repeats)

    rho = F.effective_radii(tree, cfg)

    p2m_j = jax.jit(lambda tree: F.p2m(tree, cfg))
    times["p2m"], mult_leaf = _timed(p2m_j, tree, repeats=repeats)

    def all_m2m(tree, leaf):
        m = [None] * (cfg.nlevels + 1)
        m[cfg.nlevels] = leaf
        for l in range(cfg.nlevels - 1, -1, -1):
            m[l] = F.m2m_level(m[l + 1], tree, l, cfg, rho[l + 1], rho[l])
        return m

    m2m_j = jax.jit(all_m2m)
    times["m2m"], mult = _timed(m2m_j, tree, mult_leaf, repeats=repeats)

    hm = jnp.asarray(E.m2l_matrix(cfg.p), dtype=cfg.real_dtype)

    def all_m2l(tree, conn, mult):
        if be.m2l_fused is not None:
            # single launch covering every level (downward_fused path)
            return be.m2l_fused(mult, conn.weak, tree.centers, cfg, rho)
        if be.m2l is not None:
            return [be.m2l(mult[l], conn.weak[l], tree.centers[l], cfg,
                           rho[l])
                    for l in range(1, cfg.nlevels + 1)]
        return [F.m2l_level(mult[l], conn.weak[l], tree.centers[l], cfg, hm,
                            rho[l])
                for l in range(1, cfg.nlevels + 1)]

    m2l_j = jax.jit(all_m2l)
    times["m2l"], locs = _timed(m2l_j, tree, conn, mult, repeats=repeats)

    def all_l2l(tree, locs):
        local = jnp.zeros((1, cfg.p + 1), locs[0].dtype)
        for l in range(1, cfg.nlevels + 1):
            local = F.l2l_level(local, tree, l, cfg, rho[l], rho[l - 1]) \
                + locs[l - 1]
        return local

    l2l_j = jax.jit(all_l2l)
    times["l2l"], local = _timed(l2l_j, tree, locs, repeats=repeats)

    idx_np = leaf_particle_index(cfg)
    idx = jnp.asarray(idx_np)
    if cfg.use_p2l_m2p and cfg.nlevels > 0:
        if be.p2l is not None:
            p2l_j = jax.jit(lambda local, tree, conn: local
                            + be.p2l(tree, conn, cfg, idx_np,
                                     rho[cfg.nlevels]))
        else:
            p2l_j = jax.jit(lambda local, tree, conn: F.p2l_sweep(
                local, tree, conn, cfg, idx, rho[cfg.nlevels]))
        times["p2l"], local = _timed(p2l_j, local, tree, conn,
                                     repeats=repeats)

    if be.eval_fused is not None:
        # the whole evaluation phase (L2P + M2P + P2P) is ONE launch on
        # this backend: time it as the first-class entry it compiles to
        ef_j = jax.jit(lambda local, leaf, tree, conn: be.eval_fused(
            local, leaf, tree, conn, cfg, idx_np))
        times["eval_fused"], phi = _timed(ef_j, local, mult_leaf, tree,
                                          conn, repeats=repeats)
        return times

    if be.l2p is not None:
        l2p_j = jax.jit(lambda local, tree: be.l2p(local, tree, cfg, idx_np))
    else:
        l2p_j = jax.jit(lambda local, tree: F.l2p(local, tree, cfg))
    times["l2p"], phi = _timed(l2p_j, local, tree, repeats=repeats)

    if cfg.use_p2l_m2p:
        m2p_j = jax.jit(lambda phi, leaf, tree, conn: F.m2p_sweep(
            phi, leaf, tree, conn, cfg))
        times["m2p"], phi = _timed(m2p_j, phi, mult_leaf, tree, conn,
                                   repeats=repeats)

    if be.p2p is not None:
        p2p_j = jax.jit(lambda phi, tree, conn: phi
                        + be.p2p(tree, conn, cfg, idx_np))
    else:
        p2p_j = jax.jit(lambda phi, tree, conn: F.p2p_sweep(
            phi, tree, conn, cfg, idx))
    times["p2p"], phi = _timed(p2p_j, phi, tree, conn, repeats=repeats)
    return times


def run(n: int = 45 * 256, p: int = 10, dist: str = "uniform",
        backend: str = "auto", repeats: int = 3):
    """Benchmark-harness entry: per-phase rows on the *dispatched* backend.

    Complements ``table5_1`` (always the reference sweeps) by timing the
    phases the selected backend actually runs — on TPU the pallas path
    reports the fused evaluation phase (``eval_fused``) as one entry.
    """
    from repro.configs.fmm2d import fmm_config

    z, q = particles(dist, n, 0)
    cfg = fmm_config(n, p=p)
    resolved = get_backend(backend, cfg).name
    times = phase_times(jnp.asarray(z), jnp.asarray(q), cfg,
                        repeats=repeats, backend=resolved)
    rows = [(f"fmm_phases/{k}", v * 1e6, resolved)
            for k, v in times.items()]
    total = sum(v for k, v in times.items() if k != "topology")
    rows.append(("fmm_phases/total", total * 1e6,
                 f"backend={resolved} N={n} p={p} levels={cfg.nlevels}"))
    return rows

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration harness: one dry-run cell with ModelConfig overrides.

    PYTHONPATH=src python scripts/perf_iter.py --arch qwen2-72b \
        --shape train_4k --tag remat_dots --set remat=dots
"""
import argparse
import dataclasses
import json
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")


def parse_val(v):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args()

    import jax
    from repro.configs import SHAPES, get_config, get_opt
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.launch.dryrun import cost_analysis_dict, memory_analysis_dict
    from repro.launch.hlo_analysis import collective_bytes_weighted

    overrides = dict(kv.split("=", 1) for kv in args.set)
    overrides = {k: parse_val(v) for k, v in overrides.items()}
    cfg = dataclasses.replace(get_config(args.arch), **overrides)
    shape = SHAPES[args.shape]
    multi_pod = args.mesh == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)

    t0 = time.time()
    with jax.set_mesh(mesh):
        cell = build_cell(cfg, get_opt(args.arch), shape, mesh, multi_pod)
        compiled = cell.jitted.lower(*cell.args).compile()
        mem = memory_analysis_dict(compiled)
        cost = cost_analysis_dict(compiled)
        coll = collective_bytes_weighted(compiled.as_text())

    # analytic roofline with the modified config
    from benchmarks.roofline import roofline_row
    rec = {"status": "ok", "arch": args.arch, "shape": args.shape,
           "mesh": args.mesh, "kind": cell.kind,
           "n_chips": int(mesh.devices.size), "collectives": coll,
           "memory": mem, "cost": cost}
    row = roofline_row(rec, cfg=cfg, shape=shape)
    out = {**rec, "tag": args.tag, "overrides": overrides,
           "terms": row["terms"], "fraction": row["fraction"],
           "dominant": row["dominant"],
           "hbm_analytic": row["hbm_analytic"],
           "compile_s": round(time.time() - t0, 1)}
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{args.mesh}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=str)
    t = row["terms"]
    print(f"[perf_iter] {args.tag}: frac={row['fraction']:.3f} "
          f"dom={row['dominant']} c={t['compute_s']:.3e} "
          f"m={t['memory_s']:.3e} x={t['collective_s']:.3e} "
          f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.1f}GiB "
          f"hbm_analytic={row['hbm_analytic']/2**30:.1f}GiB")


if __name__ == "__main__":
    main()

"""Compare a fresh benchmark record against the committed baseline.

    python scripts/bench_compare.py BENCH_baseline.json bench.json \
        [--threshold 0.25] [--min-us 200] [--relative] [--all]

Fails (exit 1) when any *phase timing* row — ``table5_1/*``,
``fmm_phases/*``, the batched-serving ``batched/*``/``serving/*`` and
the ``guarded/*`` entries — regresses by more than ``--threshold``
(default 25%)
relative to the baseline. Rows below ``--min-us`` in the baseline are
skipped (timer noise dominates there), as are rows present in only one
record (phases legitimately appear/disappear when backends change —
e.g. l2p/m2p/p2p collapsing into eval_fused). ``--all`` widens the
comparison to every row instead of just the phase entries.

Absolute wall-clock only transfers between identical machines; the
committed baseline and a CI runner are not. ``--relative`` (what CI
uses) therefore normalizes every per-row ratio by the *median* ratio
across the compared rows — a robust estimate of the machine-speed
factor: a uniformly slower runner moves every ratio equally and the
median divides it away, while a genuinely regressed phase sticks out
above the median. (Deliberate trade-off: a wholesale slowdown of MOST
phases shifts the median itself and is invisible to this mode — the
absolute mode, run on the baseline machine, is the check for that.)

CI runs this on the ``--quick`` record (see .github/workflows/ci.yml)
and uploads both JSONs as artifacts, so the perf trajectory is both
archived and *enforced* commit over commit.
"""
from __future__ import annotations

import argparse
import json
import statistics

PHASE_PREFIXES = ("table5_1/", "fmm_phases/", "batched/", "guarded/",
                  "serving/")


def _rows(record: dict) -> dict[str, float]:
    return {r["name"]: float(r["us_per_call"]) for r in record["results"]}


def compare(baseline: dict, fresh: dict, *, threshold: float = 0.25,
            min_us: float = 200.0, phases_only: bool = True,
            relative: bool = False):
    """Returns (violations, checked): (name, base_us, new_us, ratio)
    rows whose ratio exceeds 1 + threshold. With ``relative=True`` the
    ratio is normalized by the median ratio over the compared rows
    (machine-speed factor), so only rows regressing *relative to the
    rest of the record* are flagged.
    """
    base, new = _rows(baseline), _rows(fresh)
    checked = []
    for name, b_us in sorted(base.items()):
        if phases_only and not name.startswith(PHASE_PREFIXES):
            continue
        if name.endswith("_cold"):
            # compile-dominated rows (first-trace walks): XLA compile
            # time doesn't track the runtime machine-speed factor that
            # --relative divides away, so gating them is pure flake
            continue
        if name not in new or b_us < min_us:
            continue
        n_us = new[name]
        ratio = n_us / b_us if b_us > 0 else float("inf")
        checked.append((name, b_us, n_us, ratio))
    if relative and checked:
        scale = statistics.median(r for _, _, _, r in checked)
        if scale > 0:
            checked = [(name, b, n, r / scale)
                       for name, b, n, r in checked]
    violations = [row for row in checked if row[3] > 1.0 + threshold]
    return violations, checked


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional regression (0.25 = +25%%)")
    ap.add_argument("--min-us", type=float, default=200.0,
                    help="skip rows whose baseline is below this (noise)")
    ap.add_argument("--relative", action="store_true",
                    help="normalize ratios by the median ratio (portable "
                         "across machines; catches localized regressions)")
    ap.add_argument("--all", action="store_true",
                    help="compare every row, not just the phase entries")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    violations, checked = compare(baseline, fresh,
                                  threshold=args.threshold,
                                  min_us=args.min_us,
                                  phases_only=not args.all,
                                  relative=args.relative)
    if not checked:
        print("bench_compare: no comparable rows "
              f"(baseline rev {baseline.get('rev')}, "
              f"fresh rev {fresh.get('rev')})")
        return 0
    unit = "median-normalized" if args.relative else "absolute"
    print(f"bench_compare: {baseline.get('rev')} -> {fresh.get('rev')}, "
          f"{len(checked)} rows, threshold +{args.threshold:.0%} ({unit})")
    for name, b_us, n_us, ratio in checked:
        flag = "  REGRESSION" if (name, b_us, n_us, ratio) in violations \
            else ""
        print(f"  {name:40s} {b_us:12.1f} -> {n_us:12.1f} us "
              f"({ratio:6.2f}x){flag}")
    if violations:
        print(f"bench_compare: FAIL — {len(violations)} phase(s) regressed "
              f"more than {args.threshold:.0%}")
        return 1
    print("bench_compare: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Property-based degenerate-input tests (robustness satellite).

Every degenerate layout must end in one of two honest outcomes:
direct-oracle parity, or a *typed* error from ``repro.errors`` — never a
silent NaN/Inf and never a silently truncated phi. Coincident points are
the interesting case: the FMM's P2P excludes self-interaction by
particle identity, so coincident *distinct* particles divide by zero —
the health plane flags it and the guard's capless direct rung (which
excludes by ``x_j != y_i``, eq. (1.2)) recovers exact answers.

Uses ``tests/_hypothesis_fallback``: real property tests with hypothesis
installed, a fixed-seed deterministic sampler without it.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_fallback import given, settings, st
from repro.core import FmmConfig, direct_potential_numpy
from repro.data.synthetic import particles
from repro.errors import FmmError
from repro.solver import GuardedSolver

CFG = FmmConfig(n=256, nlevels=2, p=12, dtype="f64",
                strong_cap=32, weak_cap=64)


def _guarded():
    return GuardedSolver(CFG, "reference", max_cap_doublings=2)


def _run(z, q):
    """(phi, report) or a typed FmmError — anything else is a bug."""
    z, q = jnp.asarray(z, jnp.complex128), jnp.asarray(q, jnp.complex128)
    try:
        phi, rep = _guarded().apply_guarded(z, q)
    except FmmError:
        return None, None
    assert np.isfinite(np.asarray(phi)).all(), \
        "guarded phi must never carry silent NaN/Inf"
    return np.asarray(phi), rep


def _oracle(z, q):
    return direct_potential_numpy(z, z, np.asarray(q, np.complex128),
                                  kernel=CFG.kernel)


def _check_parity(z, q, tol):
    phi, rep = _run(z, q)
    if phi is None:          # typed refusal is an allowed honest outcome
        return
    ref = _oracle(z, q)
    scale = max(np.abs(ref).max(), 1e-12)
    assert np.abs(phi - ref).max() / scale < tol, rep.summary()


# ---------------------------------------------------------------------------
# coincident particles: non-finite FMM, exact direct recovery
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.floats(0.05, 0.95), st.floats(0.05, 0.95))
def test_all_coincident_points_recover_exactly(x, y):
    z = np.full(CFG.n, x + 1j * y, np.complex128)
    q = np.ones(CFG.n, np.complex128)
    phi, rep = _run(z, q)
    assert phi is not None, "coincident input is recoverable via direct"
    assert rep.final_rung == "direct", rep.summary()
    # the oracle excludes every coincident pair: phi is exactly zero
    np.testing.assert_array_equal(phi, np.zeros(CFG.n, np.complex128))


def test_one_distinct_particle_amid_a_coincident_cluster():
    z = np.full(CFG.n, 0.25 + 0.25j, np.complex128)
    z[0] = 0.75 + 0.75j
    q = np.ones(CFG.n, np.complex128)
    _check_parity(z, q, 1e-10)      # direct rung: exact parity


# ---------------------------------------------------------------------------
# collinear / clustered layouts: healthy FMM at oracle accuracy
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 1000), st.floats(0.1, 0.9))
def test_collinear_points(seed, height):
    rng = np.random.default_rng(seed)
    z = rng.uniform(0, 1, CFG.n) + 1j * height    # one horizontal line
    q = rng.normal(size=CFG.n) + 0j
    _check_parity(z, q, 1e-5)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 1000))
def test_empty_quadrants(seed):
    """Everything crowded into one corner: 3/4 of the boxes are empty,
    the adaptive lists must still cover every pair."""
    rng = np.random.default_rng(seed)
    z = (rng.uniform(0, 0.25, CFG.n) + 1j * rng.uniform(0, 0.25, CFG.n))
    q = rng.normal(size=CFG.n) + 0j
    _check_parity(z, q, 1e-5)


# ---------------------------------------------------------------------------
# extreme coordinate scales
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.floats(-9.0, 6.0))
def test_extreme_scales(log10_scale):
    """The tree normalizes to the data's own bounding box, so a layout
    spanning 1e-9..1e6 in absolute size must keep oracle parity."""
    scale = 10.0 ** log10_scale
    z, q = particles("uniform", CFG.n, 42)
    z = np.asarray(z, np.complex128) * scale
    _check_parity(z, np.asarray(q, np.complex128), 1e-5)


def test_single_particle_like_input_never_nan():
    """n-1 charges zeroed: numerically a one-particle problem."""
    z, q = particles("uniform", CFG.n, 7)
    q = np.zeros(CFG.n, np.complex128)
    phi, rep = _run(np.asarray(z, np.complex128), q)
    assert phi is not None
    np.testing.assert_allclose(phi, np.zeros(CFG.n), atol=1e-14)


def test_guard_rejects_nonsense_shapes_with_typed_errors():
    from repro.errors import ShapeError
    g = _guarded()
    z, q = particles("uniform", CFG.n, 1)
    with pytest.raises(ShapeError):
        g.apply_guarded(jnp.asarray(z)[:-1], jnp.asarray(q))

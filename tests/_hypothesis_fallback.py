"""Graceful degrade when the `hypothesis` library is absent.

The property tests import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly. With hypothesis installed (the CI
``[test]`` extra) they run as real property tests; without it, a minimal
fixed-seed sampler replays a handful of deterministic examples per test
— a smoke net rather than a collection error, covering exactly the
strategy subset this suite uses (``st.integers``, ``st.floats``).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import numpy as np

    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class _Strategies:
        @staticmethod
        def integers(lo: int, hi: int) -> _Strategy:
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo: float, hi: float) -> _Strategy:
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    st = _Strategies()

    def settings(max_examples: int = _FALLBACK_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = min(max_examples, _FALLBACK_EXAMPLES)
            return fn

        return deco

    def given(*strategies: _Strategy):
        def deco(fn):
            # NB: zero-arg wrapper (no functools.wraps) — pytest must not
            # see the strategy-supplied parameters as fixture requests.
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(getattr(wrapper, "_max_examples",
                                       _FALLBACK_EXAMPLES)):
                    fn(*(s.sample(rng) for s in strategies))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

"""Device-resident topology subsystem: single-sort tree parity +
sort-count pin, batched/Pallas connectivity parity against a brute-force
theta oracle, overflow semantics, static-layout vectorization."""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, st
from _jaxpr import count_sorts

from repro.core import FmmConfig, build_connectivity
from repro.core.topology import (build_tree, build_tree_lexsort,
                                 connectivity_stats, leaf_particle_index,
                                 leaf_particle_index_loop)
from repro.data.synthetic import particles
from repro.kernels.topology import leaf_classify_pallas


def _tree_pair(n, levels, dist="uniform", seed=0, **kw):
    z, q = particles(dist, n, seed)
    cfg = FmmConfig(n=n, nlevels=levels, p=5, dtype="f64", **kw)
    z, q = jnp.asarray(z), jnp.asarray(q)
    return cfg, build_tree(z, q, cfg), build_tree_lexsort(z, q, cfg)


# ---------------------------------------------------------------------------
# single-sort tree build
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,levels,dist",
                         [(64, 1, "uniform"), (257, 2, "normal"),
                          (1024, 3, "layer"), (50, 0, "normal"),
                          (4096, 3, "normal")])
def test_tree_parity_with_lexsort_oracle(n, levels, dist):
    """Rank layout bit-identical to the seed lexsort cascade."""
    cfg, new, old = _tree_pair(n, levels, dist, seed=n)
    assert (np.asarray(new.perm) == np.asarray(old.perm)).all()
    assert (np.asarray(new.z) == np.asarray(old.z)).all()
    assert (np.asarray(new.q) == np.asarray(old.q)).all()
    for l in range(levels + 1):
        assert (np.asarray(new.centers[l]) == np.asarray(old.centers[l])).all()
        assert (np.asarray(new.radii[l]) == np.asarray(old.radii[l])).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_tree_parity_randomized_sweep(seed):
    dist = ["uniform", "normal", "layer"][seed % 3]
    cfg, new, old = _tree_pair(512, 2, dist, seed=seed)
    assert (np.asarray(new.perm) == np.asarray(old.perm)).all()
    for l in range(cfg.nlevels + 1):
        assert (np.asarray(new.centers[l]) == np.asarray(old.centers[l])).all()
        assert (np.asarray(new.radii[l]) == np.asarray(old.radii[l])).all()


def test_build_tree_at_most_two_sorts():
    """The single-sort scheme: ≤ 2 full-array sorts regardless of depth
    (the seed cascade did one lexsort per split = 2*nlevels)."""
    for levels in (1, 2, 3):
        n = 64 * 4**levels
        cfg = FmmConfig(n=n, nlevels=levels, p=5, dtype="f64")
        z, q = particles("uniform", n, 0)
        jx = jax.make_jaxpr(functools.partial(build_tree, cfg=cfg))(
            jnp.asarray(z), jnp.asarray(q))
        assert count_sorts(jx.jaxpr) <= 2, levels
        jo = jax.make_jaxpr(functools.partial(build_tree_lexsort, cfg=cfg))(
            jnp.asarray(z), jnp.asarray(q))
        assert count_sorts(jo.jaxpr) == 2 * levels  # what we replaced


def test_connectivity_compaction_is_batched():
    """One flattened compaction sort + the (L-1) in-loop strong compacts:
    ≤ L sorts total (the seed did 2L + 3 per-level compactions)."""
    cfg = FmmConfig(n=1024, nlevels=3, p=5, dtype="f64")
    z, q = particles("uniform", 1024, 0)
    tree = build_tree(jnp.asarray(z), jnp.asarray(q), cfg)
    jx = jax.make_jaxpr(functools.partial(build_connectivity, cfg=cfg))(tree)
    assert count_sorts(jx.jaxpr) <= cfg.nlevels


def test_leaf_particle_index_matches_loop_oracle():
    for n, levels in [(64, 1), (300, 2), (1024, 3), (50, 0), (257, 2)]:
        cfg = FmmConfig(n=n, nlevels=levels, p=5, dtype="f64")
        assert (leaf_particle_index(cfg)
                == leaf_particle_index_loop(cfg)).all(), (n, levels)


# ---------------------------------------------------------------------------
# connectivity vs a brute-force theta oracle
# ---------------------------------------------------------------------------

def _conn_oracle(tree, cfg):
    """Dense numpy recursion: candidates = children of the parent's
    strong set, classified by the raw theta predicates — no caps, no
    compaction, no padding tricks."""
    centers = [np.asarray(c) for c in tree.centers]
    radii = [np.asarray(r) for r in tree.radii]
    t = cfg.theta
    strong = {0: [0]}
    weak = {l: {} for l in range(cfg.nlevels + 1)}
    for l in range(1, cfg.nlevels + 1):
        nxt = {}
        for b in range(4**l):
            nxt[b], weak[l][b] = [], []
            for s in strong[b // 4]:
                for c in (4 * s, 4 * s + 1, 4 * s + 2, 4 * s + 3):
                    d = np.hypot(centers[l][b].real - centers[l][c].real,
                                 centers[l][b].imag - centers[l][c].imag)
                    big = max(radii[l][b], radii[l][c])
                    small = min(radii[l][b], radii[l][c])
                    if big + t * small <= t * d:
                        weak[l][b].append(c)
                    else:
                        nxt[b].append(c)
        strong = nxt
    p2p, p2l, m2p = {}, {}, {}
    L = cfg.nlevels
    for b in range(4**L):
        p2p[b], p2l[b], m2p[b] = [], [], []
        for c in strong[b]:
            d = np.hypot(centers[L][b].real - centers[L][c].real,
                         centers[L][b].imag - centers[L][c].imag)
            rb, rc = radii[L][b], radii[L][c]
            swapped = min(rb, rc) + t * max(rb, rc) <= t * d
            if cfg.use_p2l_m2p and swapped and rc > rb:
                p2l[b].append(c)
            elif cfg.use_p2l_m2p and swapped and rc < rb:
                m2p[b].append(c)
            else:
                p2p[b].append(c)
    return weak, p2p, p2l, m2p


def _assert_matches_oracle(tree, conn, cfg):
    weak_o, p2p_o, p2l_o, m2p_o = _conn_oracle(tree, cfg)
    for l in range(1, cfg.nlevels + 1):
        got = np.asarray(conn.weak[l])
        for b in range(4**l):
            assert sorted(got[b][got[b] >= 0].tolist()) == sorted(
                weak_o[l][b]), ("weak", l, b)
    for name, oracle in (("p2p", p2p_o), ("p2l", p2l_o), ("m2p", m2p_o)):
        got = np.asarray(getattr(conn, name))
        for b in range(4**cfg.nlevels):
            assert sorted(got[b][got[b] >= 0].tolist()) == sorted(
                oracle[b]), (name, b)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_connectivity_matches_theta_oracle_clustered(seed):
    """Property: strong/weak/P2L/M2P lists == the brute-force theta
    classification, on clustered (adaptivity-stressing) inputs."""
    dist = ["normal", "layer"][seed % 2]
    cfg = FmmConfig(n=512, nlevels=2, p=5, dtype="f64")
    z, q = particles(dist, 512, seed)
    tree = build_tree(jnp.asarray(z), jnp.asarray(q), cfg)
    conn = build_connectivity(tree, cfg)
    assert int(conn.overflow) == 0
    _assert_matches_oracle(tree, conn, cfg)


def test_connectivity_oracle_without_p2l_m2p():
    cfg = FmmConfig(n=512, nlevels=2, p=5, dtype="f64", use_p2l_m2p=False)
    z, q = particles("normal", 512, 3)
    tree = build_tree(jnp.asarray(z), jnp.asarray(q), cfg)
    conn = build_connectivity(tree, cfg)
    _assert_matches_oracle(tree, conn, cfg)
    assert int((np.asarray(conn.p2l) >= 0).sum()) == 0
    assert int((np.asarray(conn.m2p) >= 0).sum()) == 0


# ---------------------------------------------------------------------------
# overflow fires exactly when a cap is exceeded
# ---------------------------------------------------------------------------

def test_overflow_fires_exactly_at_cap():
    z, q = particles("normal", 1024, 5)
    z, q = jnp.asarray(z), jnp.asarray(q)
    roomy = FmmConfig(n=1024, nlevels=3, p=5, dtype="f64",
                      strong_cap=64, weak_cap=256)
    tree = build_tree(z, q, roomy)
    stats = connectivity_stats(build_connectivity(tree, roomy))
    assert stats["overflow"] == 0
    smax, wmax = stats["strong_max"], stats["weak_max"]
    assert smax > 1 and wmax > 1

    # caps exactly at the measured occupancy: nothing truncates anywhere,
    # so the overflow flag must stay clean...
    tight = FmmConfig(n=1024, nlevels=3, p=5, dtype="f64",
                      strong_cap=smax, weak_cap=wmax)
    conn = build_connectivity(build_tree(z, q, tight), tight)
    assert int(conn.overflow) == 0

    # ...and one below either cap must fire it (by exactly the excess:
    # the box at max occupancy drops one entry)
    s_under = FmmConfig(n=1024, nlevels=3, p=5, dtype="f64",
                        strong_cap=smax - 1, weak_cap=wmax)
    conn = build_connectivity(build_tree(z, q, s_under), s_under)
    assert int(conn.overflow) >= 1
    w_under = FmmConfig(n=1024, nlevels=3, p=5, dtype="f64",
                        strong_cap=smax, weak_cap=wmax - 1)
    conn = build_connectivity(build_tree(z, q, w_under), w_under)
    assert int(conn.overflow) == 1


# ---------------------------------------------------------------------------
# Pallas leaf-classification kernel (topology backend hook)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist,kw", [("uniform", {}), ("normal", {}),
                                     ("layer", {}),
                                     ("normal", {"use_p2l_m2p": False}),
                                     ("layer", {"tile_boxes": 3})])
def test_pallas_leaf_classify_bit_parity(dist, kw):
    """build_connectivity(pallas hook) == build_connectivity(reference)
    bit-for-bit on every list of every level."""
    cfg = FmmConfig(n=1024, nlevels=3, p=5, dtype="f64", **kw)
    z, q = particles(dist, 1024, 11)
    tree = build_tree(jnp.asarray(z), jnp.asarray(q), cfg)
    ref = build_connectivity(tree, cfg)
    pal = build_connectivity(tree, cfg,
                             leaf_classify_impl=leaf_classify_pallas)
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(pal)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_connectivity_stats_single_transfer_semantics():
    """stats accept device arrays AND already-fetched numpy pytrees."""
    cfg = FmmConfig(n=256, nlevels=2, p=5, dtype="f64")
    z, q = particles("uniform", 256, 0)
    conn = build_connectivity(build_tree(jnp.asarray(z), jnp.asarray(q),
                                         cfg), cfg)
    on_device = connectivity_stats(conn)
    on_host = connectivity_stats(jax.device_get(conn))
    assert on_device == on_host
    assert on_device["p2p_pairs"] > 0

"""Topological-phase invariants: balanced pyramid, static layout,
theta-criterion completeness (every pair covered exactly once)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import (FmmConfig, build_connectivity, build_tree,
                        leaf_ids, leaf_particle_index)
from repro.core.config import level_bounds
from repro.data.synthetic import particles


def _tree(n, levels, dist="uniform", seed=0, **kw):
    z, q = particles(dist, n, seed)
    cfg = FmmConfig(n=n, nlevels=levels, p=5, dtype="f64", **kw)
    return cfg, build_tree(jnp.asarray(z), jnp.asarray(q), cfg)


@pytest.mark.parametrize("n,levels", [(64, 1), (257, 2), (1024, 3)])
def test_balanced_leaves(n, levels):
    cfg, tree = _tree(n, levels)
    lb = level_bounds(cfg)[-1]
    sizes = np.diff(lb)
    assert sizes.min() >= n // 4**levels
    assert sizes.max() <= -(-n // 4**levels) + 1
    assert sizes.sum() == n


def test_perm_is_permutation_and_boxes_contain_points():
    cfg, tree = _tree(512, 2)
    perm = np.asarray(tree.perm)
    assert sorted(perm.tolist()) == list(range(512))
    # every particle within its leaf's bounding radius
    lid = leaf_ids(cfg)
    z = np.asarray(tree.z)
    c = np.asarray(tree.centers[cfg.nlevels])[lid]
    r = np.asarray(tree.radii[cfg.nlevels])[lid]
    assert (np.abs(z - c) <= r + 1e-12).all()


def test_leaf_particle_index_static_layout():
    cfg, _ = _tree(300, 2)
    idx = leaf_particle_index(cfg)
    flat = idx[idx >= 0]
    assert sorted(flat.tolist()) == list(range(300))
    lb = level_bounds(cfg)[-1]
    for b in range(16):
        got = idx[b][idx[b] >= 0]
        assert (got == np.arange(lb[b], lb[b + 1])).all()


@pytest.mark.parametrize("dist", ["uniform", "normal", "layer"])
def test_theta_criterion_on_weak_pairs(dist):
    """Every weak (M2L) pair must satisfy the separation criterion (2.1)."""
    cfg, tree = _tree(2048, 3, dist)
    conn = build_connectivity(tree, cfg)
    assert int(conn.overflow) == 0
    for l in range(1, cfg.nlevels + 1):
        c = np.asarray(tree.centers[l])
        r = np.asarray(tree.radii[l])
        weak = np.asarray(conn.weak[l])
        for b in range(weak.shape[0]):
            for s in weak[b][weak[b] >= 0]:
                d = abs(c[b] - c[s])
                big, small = max(r[b], r[s]), min(r[b], r[s])
                assert big + cfg.theta * small <= cfg.theta * d + 1e-9


@pytest.mark.parametrize("dist,seed", [("uniform", 0), ("normal", 1),
                                       ("layer", 2)])
def test_pair_coverage_exactly_once(dist, seed):
    """Completeness: each leaf-box pair is handled by exactly one of
    {weak@some level (via ancestors), leaf p2p, leaf p2l, leaf m2p}."""
    n, L = 512, 2
    cfg, tree = _tree(n, L, dist, seed)
    conn = build_connectivity(tree, cfg)
    nb = 4**L
    count = np.zeros((nb, nb), dtype=int)

    def descendants(box, l):
        span = 4 ** (L - l)
        return range(box * span, (box + 1) * span)

    for l in range(1, L + 1):
        weak = np.asarray(conn.weak[l])
        for b in range(weak.shape[0]):
            for s in weak[b][weak[b] >= 0]:
                for db in descendants(b, l):
                    for ds in descendants(s, l):
                        count[db, ds] += 1
    for name in ("p2p", "p2l", "m2p"):
        lst = np.asarray(getattr(conn, name))
        for b in range(nb):
            for s in lst[b][lst[b] >= 0]:
                count[b, s] += 1
    assert (count == 1).all(), f"coverage min {count.min()} max {count.max()}"


def test_p2l_m2p_are_symmetric_partners():
    """If (b <- src) is P2L then (src <- b) must be M2P (directed lists)."""
    cfg, tree = _tree(2048, 3, "normal")
    conn = build_connectivity(tree, cfg)
    p2l = np.asarray(conn.p2l)
    m2p = np.asarray(conn.m2p)
    pairs_p2l = {(b, s) for b in range(p2l.shape[0])
                 for s in p2l[b][p2l[b] >= 0]}
    pairs_m2p = {(b, s) for b in range(m2p.shape[0])
                 for s in m2p[b][m2p[b] >= 0]}
    assert pairs_p2l == {(s, b) for (b, s) in pairs_m2p}


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_tree_deterministic(seed):
    cfg1, t1 = _tree(256, 2, "uniform", seed % 3)
    cfg2, t2 = _tree(256, 2, "uniform", seed % 3)
    assert (np.asarray(t1.perm) == np.asarray(t2.perm)).all()

"""Checkpointing, data pipeline, compression, straggler/failure
handling — the distributed-runtime substrate."""
import os
import subprocess
import sys
import tempfile

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, st

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data.synthetic import DataConfig, Prefetcher, lm_batch, particles
from repro.launch.runtime import FailureInjector, StragglerMonitor, train_loop
from repro.parallel import dequantize_int8, quantize_int8


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_retention():
    tree = {"a": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.int32(7)}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        for s in (1, 2, 3):
            cm.save(s, tree)
        cm.wait()
        restored, step = cm.restore_latest()
        assert step == 3
        np.testing.assert_allclose(np.asarray(restored["a"]["w"]),
                                   np.arange(12.0).reshape(3, 4))
        kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert kept == ["step_00000002", "step_00000003"]


def test_checkpoint_atomicity_no_partial_dirs():
    tree = {"w": jnp.zeros((128, 128))}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, tree)
        names = os.listdir(d)
        assert names == ["step_00000005"]
        assert latest_step(d) == 5
        # corrupt detection
        leaf = os.path.join(d, "step_00000005", "w.npy")
        with open(leaf, "wb") as f:
            f.write(b"xx")
        with pytest.raises(IOError):
            restore_checkpoint(d, 5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_lm_batch_deterministic_and_learnable_structure():
    dc = DataConfig(vocab=512, batch=4, seq=32, seed=1)
    b1 = lm_batch(dc, 10)
    b2 = lm_batch(dc, 10)
    b3 = lm_batch(dc, 11)
    assert (np.asarray(b1["tokens"]) == np.asarray(b2["tokens"])).all()
    assert (np.asarray(b1["tokens"]) != np.asarray(b3["tokens"])).any()
    # labels are next-token shifted
    t = np.asarray(b1["tokens"])
    l = np.asarray(b1["labels"])
    assert (l[:, :-1] == t[:, 1:]).all()


@pytest.mark.parametrize("dist", ["uniform", "normal", "layer"])
def test_particles_in_unit_square(dist):
    z, q = particles(dist, 1000, 0)
    z = np.asarray(z)
    assert (z.real >= 0).all() and (z.real <= 1).all()
    assert (z.imag >= 0).all() and (z.imag <= 1).all()
    assert len(z) == 1000


def test_prefetcher_orders_batches():
    pf = Prefetcher(lambda s: s * s, start_step=3, depth=2)
    got = [pf.get() for _ in range(4)]
    pf.close()
    assert got == [(3, 9), (4, 16), (5, 25), (6, 36)]


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.floats(1e-6, 1e6))
def test_quantize_int8_error_bound(scale):
    x = jnp.asarray(np.random.default_rng(0).normal(size=64) * scale,
                    jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-9 * scale


def test_compressed_allreduce_multidevice_subprocess():
    """Real 8-device shard_map EF all-reduce (runs in a subprocess so the
    forced device count cannot leak into this test session)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.parallel import make_compressed_value_and_grad, init_pod_errors
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
from jax.sharding import NamedSharding, PartitionSpec as PS
w = jax.device_put(jnp.ones((8, 8)), NamedSharding(mesh, PS(None, "model")))
batch = jax.device_put(jnp.arange(16.0).reshape(8, 2),
                       NamedSharding(mesh, PS(("pod", "data"), None)))
loss_fn = lambda p, b: jnp.mean((b @ p["w"][:2, :]) ** 2)
vg = make_compressed_value_and_grad(loss_fn, mesh)
errors = jax.device_put(init_pod_errors({"w": w}, 2),
                        {"w": NamedSharding(mesh, PS("pod"))})
loss, grads, errors = jax.jit(vg)({"w": w}, batch, errors)
ref_loss, ref_g = jax.value_and_grad(loss_fn)({"w": w}, batch)
rel = np.abs(np.asarray(grads["w"]) - np.asarray(ref_g["w"])).max() / \
    np.abs(np.asarray(ref_g["w"])).max()
assert rel < 0.02, rel
assert abs(float(loss) - float(ref_loss)) < 1e-5
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert "OK" in r.stdout, r.stderr[-2000:]


# ---------------------------------------------------------------------------
# straggler / failure handling
# ---------------------------------------------------------------------------

def test_straggler_monitor_flags_slow_steps():
    m = StragglerMonitor(threshold=2.0, warmup=0)
    for i in range(10):
        m.record(i, 0.1)
    assert m.record(10, 0.5) is True
    assert m.record(11, 0.1) is False
    assert m.slow_steps == [(10, 0.5)]


def test_train_loop_failure_and_resume():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        fi = FailureInjector(fail_at=(5,))
        step_fn = lambda s, b, i: (s + 1, {"loss": 1.0})
        with pytest.raises(RuntimeError):
            train_loop(step_fn, jnp.zeros(()), lambda s: None, start_step=0,
                       num_steps=10, ckpt_manager=cm, ckpt_every=2,
                       failure=fi, log_every=0)
        restored, step = cm.restore_latest()
        state, summary = train_loop(step_fn, restored, lambda s: None,
                                    start_step=step, num_steps=10,
                                    ckpt_manager=cm, ckpt_every=2,
                                    failure=fi, log_every=0)
        assert int(state) == 10
        assert summary["last_step"] == 9

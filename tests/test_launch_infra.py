"""Launch-layer infrastructure: HLO collective accounting and sharding
rules."""
import numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.launch.hlo_analysis import (collective_bytes_weighted,
                                       shape_bytes, _split_computations)
from repro.parallel.sharding import Rules, dp_axes, maybe_shard


def test_shape_bytes():
    assert shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert shape_bytes("bf16[8,8]{1,0}") == 128
    assert shape_bytes("(f32[4], s8[16])") == 32
    assert shape_bytes("pred[]") == 1


def test_collective_weighting_by_trip_count():
    hlo = """
%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %ar = f32[64]{0} all-reduce(%x), to_apply=%add.1
}

%cond.1 (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]) parameter(0)
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %ag = f32[128]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[64]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
}
"""
    out = collective_bytes_weighted(hlo)
    assert out["all-gather"] == 128 * 4
    assert out["all-reduce"] == 10 * 64 * 4
    comps = _split_computations(hlo)
    assert set(comps) == {"body.1", "cond.1", "main"}


def test_rules_table():
    r = Rules(multi_pod=True, fsdp=True)
    t = r.table()
    assert t["ff"] == "model" and t["experts"] == "model"
    assert t["embed"] == ("pod", "data")
    assert dp_axes(False) == ("data",)
    r2 = Rules(multi_pod=False, fsdp=False)
    assert r2.table()["embed"] is None


def test_maybe_shard_no_mesh_is_identity():
    x = jnp.ones((4, 4))
    y = maybe_shard(x, PS("data", None))
    assert (np.asarray(y) == 1).all()

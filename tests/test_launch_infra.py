"""Launch-layer infrastructure: HLO collective accounting, sharding rules,
config registry, batch specs."""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.configs import ARCH_NAMES, SHAPES, applicable, get_config, get_opt
from repro.data.synthetic import batch_specs
from repro.launch.hlo_analysis import (collective_bytes_weighted,
                                       shape_bytes, _split_computations)
from repro.parallel.sharding import Rules, dp_axes, maybe_shard


def test_shape_bytes():
    assert shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert shape_bytes("bf16[8,8]{1,0}") == 128
    assert shape_bytes("(f32[4], s8[16])") == 32
    assert shape_bytes("pred[]") == 1


def test_collective_weighting_by_trip_count():
    hlo = """
%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %ar = f32[64]{0} all-reduce(%x), to_apply=%add.1
}

%cond.1 (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]) parameter(0)
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %ag = f32[128]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[64]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
}
"""
    out = collective_bytes_weighted(hlo)
    assert out["all-gather"] == 128 * 4
    assert out["all-reduce"] == 10 * 64 * 4
    comps = _split_computations(hlo)
    assert set(comps) == {"body.1", "cond.1", "main"}


def test_rules_table():
    r = Rules(multi_pod=True, fsdp=True)
    t = r.table()
    assert t["ff"] == "model" and t["experts"] == "model"
    assert t["embed"] == ("pod", "data")
    assert dp_axes(False) == ("data",)
    r2 = Rules(multi_pod=False, fsdp=False)
    assert r2.table()["embed"] is None


def test_maybe_shard_no_mesh_is_identity():
    x = jnp.ones((4, 4))
    y = maybe_shard(x, PS("data", None))
    assert (np.asarray(y) == 1).all()


def test_registry_complete():
    assert len(ARCH_NAMES) == 10
    for name in ARCH_NAMES:
        cfg = get_config(name)
        oc = get_opt(name)
        assert cfg.vocab % 256 == 0          # TP-friendly padding
        assert cfg.n_layers % len(cfg.group) == 0
        assert oc.name in ("adamw", "adafactor")


def test_shape_applicability_matrix():
    runs = {n: [s for s in SHAPES if applicable(get_config(n), s)[0]]
            for n in ARCH_NAMES}
    # exactly the ssm/hybrid archs run long_500k
    long_runners = {n for n, ss in runs.items() if "long_500k" in ss}
    assert long_runners == {"jamba-1.5-large-398b", "rwkv6-1.6b"}
    # everyone runs the other three shapes
    for n, ss in runs.items():
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(ss)


def test_batch_specs_cover_modalities():
    for name in ARCH_NAMES:
        cfg = get_config(name)
        spec = batch_specs(cfg, 8, 64)
        assert "tokens" in spec
        if cfg.arch == "encdec":
            assert "audio" in spec
        if cfg.arch == "vlm":
            assert "img" in spec
            assert spec["tokens"].shape[1] == 64 - cfg.n_img_tokens
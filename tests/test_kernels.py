"""Pallas kernels vs their pure-jnp ref.py oracles (interpret=True on CPU),
swept over shapes, dtypes and tilings, plus end-to-end pipeline
equivalence and the level-fused single-launch property."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _jaxpr import count_pallas_calls
from repro.core import (FmmConfig, fmm_build, fmm_evaluate,
                        leaf_particle_index)
from repro.core import expansions as E
from repro.data.synthetic import particles
from repro.kernels import (l2p_apply, l2p_pallas, l2p_ref, m2l_fused_apply,
                           m2l_level_apply, nbody_direct, nbody_ref,
                           p2p_apply, p2p_pallas, p2p_ref)
from repro.kernels.common import (dense_leaf_arrays, dense_rank_planes,
                                  round_up)

RNG = np.random.default_rng(7)


def _planes(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# nbody
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,dtype", [(256, jnp.float32), (512, jnp.float64),
                                     (700, jnp.float32)])
def test_nbody_kernel_vs_ref(n, dtype):
    cdt = jnp.complex64 if dtype == jnp.float32 else jnp.complex128
    tz = (RNG.uniform(0, 1, n) + 1j * RNG.uniform(0, 1, n))
    q = RNG.normal(size=n) + 1j * RNG.normal(size=n)
    # eval and source points must be bit-identical for self-exclusion
    zj = jnp.asarray(tz).astype(cdt)
    qj = jnp.asarray(q).astype(cdt)
    tzr, tzi = jnp.real(zj), jnp.imag(zj)
    qr, qi = jnp.real(qj), jnp.imag(qj)
    refr, refi = nbody_ref(tzr, tzi, tzr, tzi, qr, qi)
    got = nbody_direct(zj, zj, qj, t_tile=128, s_tile=256, interpret=True)
    rtol = 2e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.real(np.asarray(got)), np.asarray(refr),
                               rtol=rtol, atol=rtol * np.abs(refr).max())
    np.testing.assert_allclose(np.imag(np.asarray(got)), np.asarray(refi),
                               rtol=rtol, atol=rtol * np.abs(refi).max())


# ---------------------------------------------------------------------------
# p2p / m2l / l2p against refs on a real FMM plan
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["f32", "f64"])
def plan(request):
    n, levels = 1024, 2
    z, q = particles("normal", n, 11)
    cfg = FmmConfig(n=n, nlevels=levels, p=8, dtype=request.param,
                    strong_cap=40, weak_cap=64)
    pl = fmm_build(jnp.asarray(z), jnp.asarray(q), cfg)
    return cfg, pl


def test_p2p_kernel_vs_ref(plan):
    cfg, pl = plan
    idx = leaf_particle_index(cfg)
    n_pad = round_up(idx.shape[1], 128)
    zr, zi, qr, qi, _ = dense_leaf_arrays(pl.tree.z, pl.tree.q, idx, n_pad)
    rk = dense_rank_planes(idx, n_pad)
    outr, outi = p2p_pallas(pl.conn.p2p, zr[:-1], zi[:-1], rk[:-1],
                            zr, zi, qr, qi, rk, interpret=True)
    refr, refi = p2p_ref(pl.conn.p2p, zr[:-1], zi[:-1], rk[:-1],
                         zr, zi, qr, qi, rk)
    tol = 1e-3 if cfg.dtype == "f32" else 1e-9
    scale = np.abs(np.asarray(refr)).max()
    np.testing.assert_allclose(np.asarray(outr), np.asarray(refr),
                               atol=tol * scale)
    np.testing.assert_allclose(np.asarray(outi), np.asarray(refi),
                               atol=tol * scale)


def test_m2l_kernel_vs_ref(plan):
    cfg, pl = plan
    from repro.core.fmm import effective_radii, m2l_level, upward
    rho = effective_radii(pl.tree, cfg)
    mult = upward(pl.tree, cfg, rho)
    l = cfg.nlevels
    got = m2l_level_apply(mult[l], pl.conn.weak[l], pl.tree.centers[l], cfg,
                          rho[l], interpret=True)
    # oracle: the jnp m2l_level from the core pipeline
    mat = jnp.asarray(E.m2l_matrix(cfg.p), dtype=cfg.real_dtype)
    ref = m2l_level(mult[l], pl.conn.weak[l], pl.tree.centers[l], cfg, mat,
                    rho[l])
    scale = np.abs(np.asarray(ref)).max()
    tol = 2e-5 if cfg.dtype == "f32" else 1e-12
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=tol * scale)


def test_l2p_kernel_vs_ref(plan):
    cfg, pl = plan
    from repro.core.fmm import downward, upward, l2p
    mult = upward(pl.tree, cfg)
    local = downward(mult, pl.tree, pl.conn, cfg)
    idx = leaf_particle_index(cfg)
    got = l2p_apply(local, pl.tree, cfg, idx, interpret=True)
    ref = l2p(local, pl.tree, cfg)
    tol = 1e-4 if cfg.dtype == "f32" else 1e-10
    scale = max(np.abs(np.asarray(ref)).max(), 1e-9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=tol * scale)


def test_full_pipeline_with_kernels(plan):
    cfg, pl = plan
    phi_ref = fmm_evaluate(pl, cfg)

    def p2p_impl(tree, conn, c, i):
        return p2p_apply(tree, conn, c, i, interpret=True)

    def m2l_impl(mult, weak, centers, c, rho):
        return m2l_level_apply(mult, weak, centers, c, rho, interpret=True)

    if cfg.dtype == "f64":
        phi = fmm_evaluate(pl, cfg, p2p_impl=p2p_impl)
        tol = 1e-9
    else:
        phi = fmm_evaluate(pl, cfg, p2p_impl=p2p_impl, m2l_impl=m2l_impl)
        tol = 5e-4
    scale = np.abs(np.asarray(phi_ref)).max()
    np.testing.assert_allclose(np.asarray(phi), np.asarray(phi_ref),
                               atol=tol * scale)


# ---------------------------------------------------------------------------
# multi-box tiling: parity across tile_boxes (incl. ragged nbox % TB != 0)
# and both G-kernels, f64 interpret mode, <= 1e-10 relative
# ---------------------------------------------------------------------------

TILINGS = [(1, 1), (2, 1), (8, 1),   # required sweep: tile_boxes in {1,2,8}
           (3, 1), (8, 2)]           # ragged 16 % 3 != 0; staged slots


def _tiled_plan(kernel, tile_boxes, stage_width, nlevels=2):
    cfg = FmmConfig(n=1024, nlevels=nlevels, p=8, dtype="f64",
                    kernel=kernel, strong_cap=40, weak_cap=64,
                    tile_boxes=tile_boxes, stage_width=stage_width)
    z, q = particles("normal", cfg.n, 11)   # clustered (adaptive) input
    return cfg, fmm_build(jnp.asarray(z), jnp.asarray(q), cfg)


@pytest.mark.parametrize("kernel", ["harmonic", "log"])
@pytest.mark.parametrize("tb,sw", TILINGS)
def test_p2p_tiled_vs_ref(kernel, tb, sw):
    cfg, pl = _tiled_plan(kernel, tb, sw)
    idx = leaf_particle_index(cfg)
    n_pad = round_up(idx.shape[1], 128)
    zr, zi, qr, qi, _ = dense_leaf_arrays(pl.tree.z, pl.tree.q, idx, n_pad)
    rk = dense_rank_planes(idx, n_pad)
    outr, outi = p2p_pallas(pl.conn.p2p, zr[:-1], zi[:-1], rk[:-1],
                            zr, zi, qr, qi, rk,
                            kernel=kernel, tile_boxes=tb, stage_width=sw,
                            interpret=True)
    refr, refi = p2p_ref(pl.conn.p2p, zr[:-1], zi[:-1], rk[:-1],
                         zr, zi, qr, qi, rk, kernel=kernel)
    scale = np.abs(np.asarray(refr)).max()
    np.testing.assert_allclose(np.asarray(outr), np.asarray(refr),
                               atol=1e-10 * scale)
    np.testing.assert_allclose(np.asarray(outi), np.asarray(refi),
                               atol=1e-10 * scale)


@pytest.mark.parametrize("kernel", ["harmonic", "log"])
@pytest.mark.parametrize("tb,sw", TILINGS)
def test_m2l_tiled_vs_ref(kernel, tb, sw):
    from repro.core.fmm import effective_radii, m2l_level, upward
    cfg, pl = _tiled_plan(kernel, tb, sw)
    rho = effective_radii(pl.tree, cfg)
    mult = upward(pl.tree, cfg, rho)
    l = cfg.nlevels
    got = m2l_level_apply(mult[l], pl.conn.weak[l], pl.tree.centers[l],
                          cfg, rho[l], interpret=True)
    mat = jnp.asarray(E.m2l_matrix(cfg.p), dtype=cfg.real_dtype)
    ref = m2l_level(mult[l], pl.conn.weak[l], pl.tree.centers[l], cfg, mat,
                    rho[l])
    scale = np.abs(np.asarray(ref)).max()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-10 * scale)


@pytest.mark.parametrize("tb", [1, 3, 8])
def test_l2p_tiled_vs_ref(tb):
    from repro.core.fmm import downward, l2p, upward
    cfg, pl = _tiled_plan("harmonic", tb, 1)
    mult = upward(pl.tree, cfg)
    local = downward(mult, pl.tree, pl.conn, cfg)
    idx = leaf_particle_index(cfg)
    got = l2p_apply(local, pl.tree, cfg, idx, interpret=True)
    ref = l2p(local, pl.tree, cfg)
    scale = max(np.abs(np.asarray(ref)).max(), 1e-9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-10 * scale)


def test_tile_larger_than_nbox():
    """nlevels=1 -> 4 boxes with tile_boxes=8: the whole level is one
    ragged tile."""
    cfg, pl = _tiled_plan("harmonic", 8, 1, nlevels=1)
    idx = leaf_particle_index(cfg)
    n_pad = round_up(idx.shape[1], 128)
    zr, zi, qr, qi, _ = dense_leaf_arrays(pl.tree.z, pl.tree.q, idx, n_pad)
    rk = dense_rank_planes(idx, n_pad)
    outr, _ = p2p_pallas(pl.conn.p2p, zr[:-1], zi[:-1], rk[:-1],
                         zr, zi, qr, qi, rk, tile_boxes=8, interpret=True)
    refr, _ = p2p_ref(pl.conn.p2p, zr[:-1], zi[:-1], rk[:-1],
                      zr, zi, qr, qi, rk)
    scale = np.abs(np.asarray(refr)).max()
    np.testing.assert_allclose(np.asarray(outr), np.asarray(refr),
                               atol=1e-10 * scale)


# ---------------------------------------------------------------------------
# level-fused M2L: parity with the per-level downward() on a clustered
# distribution, and the single-pallas_call launch property
# ---------------------------------------------------------------------------

def _fused_impl(mult, weak, centers, cfg, rho):
    return m2l_fused_apply(mult, weak, centers, cfg, rho, interpret=True)


@pytest.mark.parametrize("kernel", ["harmonic", "log"])
def test_downward_fused_matches_downward(kernel):
    from repro.core.fmm import downward, downward_fused, upward
    cfg = FmmConfig(n=2048, nlevels=3, p=8, dtype="f64", kernel=kernel,
                    strong_cap=64, weak_cap=96, tile_boxes=8)
    z, q = particles("normal", cfg.n, 3)   # clustered (adaptive) input
    pl = fmm_build(jnp.asarray(z), jnp.asarray(q), cfg)
    mult = upward(pl.tree, cfg)
    ref = downward(mult, pl.tree, pl.conn, cfg)
    got = downward_fused(mult, pl.tree, pl.conn, cfg, _fused_impl)
    scale = np.abs(np.asarray(ref)).max()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-10 * scale)


def test_downward_fused_is_single_launch():
    """The fused downward pass issues exactly one M2L pallas_call for all
    levels; the per-level path issues one per level."""
    from repro.core.fmm import downward_fused, downward_with, upward
    cfg, pl = _tiled_plan("harmonic", 8, 1, nlevels=3)
    mult = upward(pl.tree, cfg)

    fused_jaxpr = jax.make_jaxpr(
        lambda m: downward_fused(m, pl.tree, pl.conn, cfg, _fused_impl)
    )(mult)
    assert count_pallas_calls(fused_jaxpr.jaxpr) == 1

    def per_level(m, weak, centers, c, rho):
        return m2l_level_apply(m, weak, centers, c, rho, interpret=True)

    level_jaxpr = jax.make_jaxpr(
        lambda m: downward_with(m, pl.tree, pl.conn, cfg, per_level)
    )(mult)
    assert count_pallas_calls(level_jaxpr.jaxpr) == cfg.nlevels


def test_solver_pallas_log_kernel_end_to_end():
    """backend="pallas" serves log-kernel configs (no reference fallback)."""
    from repro.solver import FmmSolver
    cfg = FmmConfig(n=512, nlevels=2, p=8, dtype="f64", kernel="log",
                    strong_cap=40, weak_cap=64)
    z, q = particles("normal", cfg.n, 11)
    z, q = jnp.asarray(z), jnp.asarray(q)
    ref = np.asarray(FmmSolver.build(cfg, "reference").apply(z, q))
    got = np.asarray(FmmSolver.build(cfg, "pallas").apply(z, q))
    scale = np.abs(ref).max()
    assert np.abs(got - ref).max() / scale < 1e-10


def test_l2p_pallas_shape_sweep():
    for nbox, n_pad, P, p in [(4, 128, 128, 5), (16, 256, 128, 17)]:
        br = _planes((nbox, P), jnp.float32)
        bi = _planes((nbox, P), jnp.float32)
        tr = _planes((nbox, n_pad), jnp.float32) * 0.1
        ti = _planes((nbox, n_pad), jnp.float32) * 0.1
        outr, outi = l2p_pallas(br, bi, tr, ti, p=p, interpret=True)
        refr, refi = l2p_ref(br, bi, tr, ti, p)
        np.testing.assert_allclose(np.asarray(outr), np.asarray(refr),
                                   rtol=2e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(outi), np.asarray(refi),
                                   rtol=2e-4, atol=1e-4)

"""Pallas kernels vs their pure-jnp ref.py oracles (interpret=True on CPU),
swept over shapes and dtypes, plus end-to-end pipeline equivalence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (FmmConfig, fmm_build, fmm_evaluate,
                        leaf_particle_index)
from repro.core import expansions as E
from repro.data.synthetic import particles
from repro.kernels import (l2p_apply, l2p_pallas, l2p_ref, m2l_level_apply,
                           nbody_direct, nbody_ref, p2p_apply, p2p_pallas,
                           p2p_ref)
from repro.kernels.common import dense_leaf_arrays, round_up

RNG = np.random.default_rng(7)


def _planes(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# nbody
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,dtype", [(256, jnp.float32), (512, jnp.float64),
                                     (700, jnp.float32)])
def test_nbody_kernel_vs_ref(n, dtype):
    cdt = jnp.complex64 if dtype == jnp.float32 else jnp.complex128
    tz = (RNG.uniform(0, 1, n) + 1j * RNG.uniform(0, 1, n))
    q = RNG.normal(size=n) + 1j * RNG.normal(size=n)
    # eval and source points must be bit-identical for self-exclusion
    zj = jnp.asarray(tz).astype(cdt)
    qj = jnp.asarray(q).astype(cdt)
    tzr, tzi = jnp.real(zj), jnp.imag(zj)
    qr, qi = jnp.real(qj), jnp.imag(qj)
    refr, refi = nbody_ref(tzr, tzi, tzr, tzi, qr, qi)
    got = nbody_direct(zj, zj, qj, t_tile=128, s_tile=256, interpret=True)
    rtol = 2e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.real(np.asarray(got)), np.asarray(refr),
                               rtol=rtol, atol=rtol * np.abs(refr).max())
    np.testing.assert_allclose(np.imag(np.asarray(got)), np.asarray(refi),
                               rtol=rtol, atol=rtol * np.abs(refi).max())


# ---------------------------------------------------------------------------
# p2p / m2l / l2p against refs on a real FMM plan
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["f32", "f64"])
def plan(request):
    n, levels = 1024, 2
    z, q = particles("normal", n, 11)
    cfg = FmmConfig(n=n, nlevels=levels, p=8, dtype=request.param,
                    strong_cap=40, weak_cap=64)
    pl = fmm_build(jnp.asarray(z), jnp.asarray(q), cfg)
    return cfg, pl


def test_p2p_kernel_vs_ref(plan):
    cfg, pl = plan
    idx = leaf_particle_index(cfg)
    n_pad = round_up(idx.shape[1], 128)
    zr, zi, qr, qi, _ = dense_leaf_arrays(pl.tree.z, pl.tree.q, idx, n_pad)
    outr, outi = p2p_pallas(pl.conn.p2p, zr[:-1], zi[:-1], zr, zi, qr, qi,
                            interpret=True)
    refr, refi = p2p_ref(pl.conn.p2p, zr[:-1], zi[:-1], zr, zi, qr, qi)
    tol = 1e-3 if cfg.dtype == "f32" else 1e-9
    scale = np.abs(np.asarray(refr)).max()
    np.testing.assert_allclose(np.asarray(outr), np.asarray(refr),
                               atol=tol * scale)
    np.testing.assert_allclose(np.asarray(outi), np.asarray(refi),
                               atol=tol * scale)


def test_m2l_kernel_vs_ref(plan):
    cfg, pl = plan
    if cfg.dtype == "f64":
        pytest.skip("pallas m2l validated in f32 (TPU target dtype)")
    from repro.core.fmm import effective_radii, m2l_level, upward
    rho = effective_radii(pl.tree, cfg)
    mult = upward(pl.tree, cfg, rho)
    l = cfg.nlevels
    got = m2l_level_apply(mult[l], pl.conn.weak[l], pl.tree.centers[l], cfg,
                          rho[l], interpret=True)
    # oracle: the jnp m2l_level from the core pipeline
    mat = jnp.asarray(E.m2l_matrix(cfg.p), dtype=cfg.real_dtype)
    ref = m2l_level(mult[l], pl.conn.weak[l], pl.tree.centers[l], cfg, mat,
                    rho[l])
    scale = np.abs(np.asarray(ref)).max()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5 * scale)


def test_l2p_kernel_vs_ref(plan):
    cfg, pl = plan
    from repro.core.fmm import downward, upward, l2p
    mult = upward(pl.tree, cfg)
    local = downward(mult, pl.tree, pl.conn, cfg)
    idx = leaf_particle_index(cfg)
    got = l2p_apply(local, pl.tree, cfg, idx, interpret=True)
    ref = l2p(local, pl.tree, cfg)
    tol = 1e-4 if cfg.dtype == "f32" else 1e-10
    scale = max(np.abs(np.asarray(ref)).max(), 1e-9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=tol * scale)


def test_full_pipeline_with_kernels(plan):
    cfg, pl = plan
    phi_ref = fmm_evaluate(pl, cfg)

    def p2p_impl(tree, conn, c, i):
        return p2p_apply(tree, conn, c, i, interpret=True)

    def m2l_impl(mult, weak, centers, c, rho):
        return m2l_level_apply(mult, weak, centers, c, rho, interpret=True)

    if cfg.dtype == "f64":
        phi = fmm_evaluate(pl, cfg, p2p_impl=p2p_impl)
        tol = 1e-9
    else:
        phi = fmm_evaluate(pl, cfg, p2p_impl=p2p_impl, m2l_impl=m2l_impl)
        tol = 5e-4
    scale = np.abs(np.asarray(phi_ref)).max()
    np.testing.assert_allclose(np.asarray(phi), np.asarray(phi_ref),
                               atol=tol * scale)


def test_l2p_pallas_shape_sweep():
    for nbox, n_pad, P, p in [(4, 128, 128, 5), (16, 256, 128, 17)]:
        br = _planes((nbox, P), jnp.float32)
        bi = _planes((nbox, P), jnp.float32)
        tr = _planes((nbox, n_pad), jnp.float32) * 0.1
        ti = _planes((nbox, n_pad), jnp.float32) * 0.1
        outr, outi = l2p_pallas(br, bi, tr, ti, p=p, interpret=True)
        refr, refi = l2p_ref(br, bi, tr, ti, p)
        np.testing.assert_allclose(np.asarray(outr), np.asarray(refr),
                                   rtol=2e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(outi), np.asarray(refi),
                                   rtol=2e-4, atol=1e-4)

"""The CI perf gate: scripts/bench_compare.py vs BENCH_baseline.json."""
import importlib.util
import json
import pathlib

REPO = pathlib.Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "bench_compare", REPO / "scripts" / "bench_compare.py")
bc = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bc)


def _rec(rows, rev="test"):
    return {"rev": rev,
            "results": [{"name": n, "us_per_call": u, "derived": ""}
                        for n, u in rows]}


def test_compare_passes_within_threshold():
    base = _rec([("table5_1/p2p", 1000.0), ("fmm_phases/sort", 500.0)])
    fresh = _rec([("table5_1/p2p", 1200.0), ("fmm_phases/sort", 400.0)])
    violations, checked = bc.compare(base, fresh, threshold=0.25)
    assert not violations
    assert len(checked) == 2


def test_compare_fails_on_regression():
    base = _rec([("fmm_phases/p2p", 1000.0)])
    fresh = _rec([("fmm_phases/p2p", 1300.0)])
    violations, _ = bc.compare(base, fresh, threshold=0.25)
    assert [v[0] for v in violations] == ["fmm_phases/p2p"]


def test_batched_serving_rows_are_gated():
    """The batched-serving entries are first-class phase rows: a
    regression of apply_batched throughput fails the gate."""
    base = _rec([("batched/B=4_batched", 1000.0)])
    fresh = _rec([("batched/B=4_batched", 1300.0)])
    violations, checked = bc.compare(base, fresh, threshold=0.25)
    assert checked
    assert [v[0] for v in violations] == ["batched/B=4_batched"]


def test_compare_skips_noise_missing_and_nonphase_rows():
    base = _rec([("fmm_phases/connect", 50.0),      # below min_us: noise
                 ("fmm_phases/l2p", 1000.0),        # gone in fresh (fused)
                 ("accuracy/err", 1000.0)])         # not a phase row
    fresh = _rec([("fmm_phases/connect", 500.0),
                  ("fmm_phases/eval_fused", 900.0),
                  ("accuracy/err", 9000.0)])
    violations, checked = bc.compare(base, fresh, threshold=0.25,
                                     min_us=200.0)
    assert not violations and not checked
    # --all widens to every matching row
    violations, checked = bc.compare(base, fresh, threshold=0.25,
                                     min_us=200.0, phases_only=False)
    assert [v[0] for v in violations] == ["accuracy/err"]


def test_relative_mode_is_machine_portable():
    """CI normalizes per-row ratios by the record's median ratio: a
    uniformly slower machine divides away, a genuinely regressed phase
    sticks out above the median."""
    base = _rec([("fmm_phases/p2p", 1000.0), ("fmm_phases/sort", 1000.0),
                 ("fmm_phases/m2l", 1000.0)])
    slower = _rec([("fmm_phases/p2p", 3000.0), ("fmm_phases/sort", 3000.0),
                   ("fmm_phases/m2l", 3000.0)])
    v_abs, _ = bc.compare(base, slower)
    assert v_abs                          # absolute us: false positive
    v_rel, checked = bc.compare(base, slower, relative=True)
    assert checked and not v_rel          # relative: clean


def test_relative_mode_flags_localized_regression_only():
    base = _rec([("fmm_phases/p2p", 4000.0), ("fmm_phases/sort", 1000.0),
                 ("fmm_phases/m2l", 1000.0)])
    # p2p genuinely 2x slower; everything else flat
    fresh = _rec([("fmm_phases/p2p", 8000.0), ("fmm_phases/sort", 1000.0),
                  ("fmm_phases/m2l", 1000.0)])
    v, _ = bc.compare(base, fresh, relative=True)
    assert [row[0] for row in v] == ["fmm_phases/p2p"]


def test_relative_mode_ignores_improvement_of_dominant_phase():
    """A dominant phase getting FASTER must not flag untouched phases
    (the failure mode of share-of-total normalization)."""
    base = _rec([("fmm_phases/p2p", 8000.0), ("fmm_phases/sort", 1000.0),
                 ("fmm_phases/m2l", 1000.0)])
    fresh = _rec([("fmm_phases/p2p", 2000.0), ("fmm_phases/sort", 1000.0),
                  ("fmm_phases/m2l", 1000.0)])
    v, checked = bc.compare(base, fresh, relative=True)
    assert checked and not v


def test_main_exit_codes(tmp_path):
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps(_rec([("fmm_phases/p2p", 1000.0)])))
    fresh.write_text(json.dumps(_rec([("fmm_phases/p2p", 1001.0)])))
    assert bc.main([str(base), str(fresh)]) == 0
    fresh.write_text(json.dumps(_rec([("fmm_phases/p2p", 2000.0)])))
    assert bc.main([str(base), str(fresh)]) == 1


def test_committed_baseline_is_readable():
    """The committed baseline must stay a valid record with phase rows
    (the CI gate reads it on every push)."""
    path = REPO / "BENCH_baseline.json"
    assert path.exists(), "BENCH_baseline.json missing (CI perf gate)"
    record = json.loads(path.read_text())
    names = {r["name"] for r in record["results"]}
    assert any(n.startswith("fmm_phases/") for n in names)
    assert any(n.startswith("table5_1/") for n in names)
    assert any(n.startswith("batched/") for n in names)

"""Guarded execution: the in-graph health plane, the recovery ladder
(cap escalation -> per-phase degradation -> direct), and the typed
error taxonomy — driven rung by rung by the fault injectors of
``repro.testing.faults``."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import FmmConfig, direct_potential
from repro.data.synthetic import particles
from repro.errors import (CapOverflowError, FmmError, NonFiniteInputError,
                          NonFiniteOutputError, RecoveryExhaustedError)
from repro.solver import FmmSolver, GuardedSolver, GuardReport
from repro.solver.guard import grow_caps
from repro.testing import (force_cap_overflow, nan_coefficients,
                           poison_input, truncate_interaction_lists)

CFG = FmmConfig(n=256, nlevels=2, p=12, dtype="f64",
                strong_cap=32, weak_cap=64)


def _problem(seed=3, dist="normal"):
    z, q = particles(dist, CFG.n, seed)
    return jnp.asarray(z), jnp.asarray(q)


def _oracle(z, q):
    return np.asarray(direct_potential(z, z, q, kernel=CFG.kernel))


# ---------------------------------------------------------------------------
# rung 0: healthy steady state
# ---------------------------------------------------------------------------

def test_guard_healthy_passthrough():
    """On a healthy input the guard is the plain apply plus one host
    read: same phi, no retries, no degradations."""
    z, q = _problem()
    g = GuardedSolver(CFG, "reference")
    phi, rep = g.apply_guarded(z, q)
    np.testing.assert_array_equal(
        np.asarray(phi),
        np.asarray(FmmSolver.build(CFG, "reference").apply(z, q)))
    assert isinstance(rep, GuardReport)
    assert rep.ok and rep.retries == 0 and rep.degradations == ()
    assert rep.final_rung == "primary"
    assert rep.margins["strong"] >= 0
    assert "primary" in rep.summary()


# ---------------------------------------------------------------------------
# rung 1: cap overflow -> targeted cap escalation, solver promotion
# ---------------------------------------------------------------------------

def test_guard_recovers_from_truncated_lists_by_cap_doubling():
    """The cap-drift fault (lists silently short) is detected by the
    margins and recovered by doubling exactly the overflowed cap
    family; the escalated solver is promoted for subsequent steps."""
    z, q = _problem()
    ref = np.asarray(FmmSolver.build(CFG, "reference").apply(z, q))
    with truncate_interaction_lists(drop=20):   # strong margin is 16
        g = GuardedSolver(CFG, "reference", max_cap_doublings=2)
        phi, rep = g.apply_guarded(z, q)
        assert rep.ok and rep.retries == 1 and rep.degradations == ()
        assert rep.attempts[0].rung == "primary"
        assert not rep.attempts[0].ok
        assert rep.attempts[0].overflow > 0
        # targeted: only the strong family overflowed, weak kept its cap
        assert g.cfg.strong_cap == 2 * CFG.strong_cap
        assert g.cfg.weak_cap == CFG.weak_cap
        # the promoted solver keeps serving healthily on the fast path
        phi2, rep2 = g.apply_guarded(z, q)
        assert rep2.retries == 0 and rep2.final_rung == "primary"
    scale = np.abs(ref).max()
    assert np.abs(np.asarray(phi) - ref).max() / scale < 1e-12
    assert np.abs(np.asarray(phi2) - ref).max() / scale < 1e-12


def test_grow_caps_targets_negative_margins():
    grown = grow_caps(CFG, {"strong": -2, "weak": 5,
                            "p2p": 1, "p2l": 1, "m2p": 1})
    assert grown.strong_cap == 2 * CFG.strong_cap
    assert grown.weak_cap == CFG.weak_cap
    grown = grow_caps(CFG, {"strong": 3, "weak": -1,
                            "p2p": 1, "p2l": 1, "m2p": 1})
    assert grown.strong_cap == CFG.strong_cap
    assert grown.weak_cap == 2 * CFG.weak_cap
    # no margins: both double, weak clamped to the structural 4S bound
    grown = grow_caps(dataclasses.replace(CFG, weak_cap=8 * CFG.strong_cap))
    assert grown.weak_cap == 4 * grown.strong_cap


# ---------------------------------------------------------------------------
# rung 3: unrecoverable overflow -> direct oracle parity (acceptance gate)
# ---------------------------------------------------------------------------

def test_guard_walks_to_direct_under_forced_overflow():
    """Acceptance: under injected cap overflow that no escalation can
    fix, apply_guarded falls through to the O(N^2) rung and returns
    direct-oracle parity (<= 1e-10, f64), with the report recording
    the whole path."""
    z, q = _problem()
    oracle = _oracle(z, q)
    with force_cap_overflow(strong=1, weak=1):
        g = GuardedSolver(CFG, "reference", max_cap_doublings=1)
        phi, rep = g.apply_guarded(z, q)
    assert rep.ok and rep.final_rung == "direct"
    assert rep.final_backend == "direct"
    rungs = [a.rung for a in rep.attempts]
    assert rungs[0] == "primary" and rungs[-1] == "direct"
    assert any(r.startswith("caps*") for r in rungs)   # escalation tried
    assert "direct" in rep.degradations
    scale = np.abs(oracle).max()
    assert np.abs(np.asarray(phi) - oracle).max() / scale <= 1e-10


def test_guard_exhaustion_raises_typed_error_with_report():
    z, q = _problem()
    with force_cap_overflow(strong=1, weak=1):
        g = GuardedSolver(CFG, "reference", max_cap_doublings=1,
                          direct=False)
        with pytest.raises(RecoveryExhaustedError) as ei:
            g.apply_guarded(z, q)
    rep = ei.value.report
    assert isinstance(rep, GuardReport) and not rep.ok
    assert rep.attempts[-1].overflow > 0
    assert isinstance(ei.value, FmmError)   # taxonomy root


# ---------------------------------------------------------------------------
# rung 2: kernel fault -> per-phase degradation
# ---------------------------------------------------------------------------

def test_guard_degrades_poisoned_kernel_phase():
    """A NaN-emitting evaluation kernel (finite input!) is flagged by
    nonfinite_output and recovered by dropping only the evaluation-phase
    hooks to the reference sweeps — caps, topology and M2L keep their
    backend."""
    z, q = _problem()
    ref = np.asarray(FmmSolver.build(CFG, "reference").apply(z, q))
    with nan_coefficients("pallas", "eval_fused"):
        g = GuardedSolver(CFG, "pallas")
        phi, rep = g.apply_guarded(z, q)
    assert rep.ok
    assert rep.attempts[0].nonfinite_output and not rep.attempts[0].ok
    assert rep.final_rung == "degrade:pallas+ref-eval"
    assert rep.degradations == ("degrade:pallas+ref-eval",)
    scale = np.abs(ref).max()
    assert np.abs(np.asarray(phi) - ref).max() / scale < 1e-10


def test_apply_checked_raises_nonfinite_output_typed():
    z, q = _problem()
    with nan_coefficients("pallas", "eval_fused"):
        solver = FmmSolver.build(CFG, "pallas")
        with pytest.raises(NonFiniteOutputError, match="kernel"):
            solver.apply_checked(z, q)


# ---------------------------------------------------------------------------
# garbage input: typed refusal, never a recovery walk
# ---------------------------------------------------------------------------

def test_guard_refuses_nonfinite_input():
    z, q = _problem()
    g = GuardedSolver(CFG, "reference")
    with pytest.raises(NonFiniteInputError, match="NaN"):
        g.apply_guarded(poison_input(z), q)
    with pytest.raises(NonFiniteInputError):
        g.apply_guarded(z, poison_input(q))


def test_apply_checked_overflow_error_carries_margins():
    tiny = dataclasses.replace(CFG, strong_cap=2, weak_cap=2)
    z, q = _problem(5)
    with pytest.raises(CapOverflowError) as ei:
        FmmSolver.build(tiny, "reference").apply_checked(z, q)
    assert ei.value.overflow > 0
    assert min(ei.value.margins.values()) < 0
    assert isinstance(ei.value, RuntimeError)   # legacy except-clauses


# ---------------------------------------------------------------------------
# batched guarded entry
# ---------------------------------------------------------------------------

def test_apply_batched_guarded_escalates_whole_batch():
    zs, qs = zip(*(particles("normal", CFG.n, s) for s in (0, 1)))
    zb = jnp.stack([jnp.asarray(z) for z in zs])
    qb = jnp.stack([jnp.asarray(q) for q in qs])
    ref = np.asarray(FmmSolver.build(CFG, "reference").apply_batched(zb, qb))
    with truncate_interaction_lists(drop=20):
        g = GuardedSolver(CFG, "reference", max_cap_doublings=2)
        phi, rep = g.apply_batched_guarded(zb, qb)
        assert rep.ok and rep.entry == "apply_batched"
        assert rep.retries >= 1
        assert g.cfg.strong_cap > CFG.strong_cap   # batch-wide promotion
    scale = np.abs(ref).max()
    assert np.abs(np.asarray(phi) - ref).max() / scale < 1e-12


# ---------------------------------------------------------------------------
# refresh_guarded: the time-stepping re-planning loop
# ---------------------------------------------------------------------------

def test_refresh_guarded_replans_on_cap_drift():
    """A drifted plan (overflowing caps) re-plans through escalation and
    promotes the solver: the next refresh is primary-healthy, and
    refresh+apply_plan matches the plain apply of the promoted config."""
    z, q = _problem(7)
    tight = dataclasses.replace(CFG, strong_cap=4, weak_cap=0)
    g = GuardedSolver(tight, "reference", max_cap_doublings=4)
    plan, rep = g.refresh_guarded(z, q)
    assert rep.ok and rep.retries >= 1
    assert int(plan.conn.overflow) == 0
    assert g.cfg.strong_cap > tight.strong_cap
    phi = g.apply_plan(plan)
    ref = FmmSolver.build(g.cfg, "reference").apply(z, q)
    np.testing.assert_allclose(np.asarray(phi), np.asarray(ref),
                               rtol=1e-12, atol=1e-12)
    # promoted: steady state is back to one attempt
    _, rep2 = g.refresh_guarded(z, q)
    assert rep2.retries == 0 and rep2.final_rung == "primary"


def test_refresh_guarded_exhaustion_raises_cap_overflow():
    z, q = _problem(7)
    with force_cap_overflow(strong=1, weak=1):
        g = GuardedSolver(CFG, "reference", max_cap_doublings=1)
        with pytest.raises(CapOverflowError, match="doubling"):
            g.refresh_guarded(z, q)


# ---------------------------------------------------------------------------
# ladder warm-up
# ---------------------------------------------------------------------------

def test_precompile_warms_the_plan_lattice():
    z, q = _problem()
    small = dataclasses.replace(CFG, p=6)
    g = GuardedSolver(small, "reference", max_cap_doublings=1)
    warmed = g.precompile(z, q)
    assert len(warmed) >= 2                      # primary + one doubling
    assert any("reference@" in w for w in warmed)
    hits_before = FmmSolver.cache_info().hits
    g.apply_guarded(z, q)                        # served from the lattice
    assert FmmSolver.cache_info().hits >= hits_before

"""Translation-operator correctness: matrix (MXU) forms vs the paper's
scaled-Horner forms vs direct evaluation, plus composition properties."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import expansions as E

RNG = np.random.default_rng(0)


def _cluster(n, center, radius):
    return center + radius * ((RNG.uniform(-1, 1, n))
                              + 1j * RNG.uniform(-1, 1, n))


def _direct(zs, xs, qs, kernel):
    d = xs[None, :] - zs[:, None]
    if kernel == "harmonic":
        return (qs[None, :] / d).sum(-1)
    return (qs[None, :] * np.log(zs[:, None] - xs[None, :])).sum(-1)


@pytest.mark.parametrize("kernel", ["harmonic", "log"])
@pytest.mark.parametrize("p", [4, 12, 24])
def test_p2m_eval_converges(kernel, p):
    xs = _cluster(50, 0.2 + 0.1j, 0.1)
    qs = RNG.normal(size=50) + 1j * RNG.normal(size=50)
    zt = _cluster(20, 2.0 - 1.0j, 0.1)
    a = E.p2m_single(jnp.asarray(xs), jnp.asarray(qs), jnp.asarray(0.2 + 0.1j),
                     p, kernel)
    got = np.asarray(E.eval_multipole(a, 0.2 + 0.1j, jnp.asarray(zt)))
    ref = _direct(zt, xs, qs, kernel)
    if kernel == "log":
        got, ref = got.real, ref.real
    scale = np.abs(ref).max()
    # sources within r~0.14 of center, targets ~2.1 away -> ratio ~0.07
    tol = max(3 * 0.15 ** p, 1e-12)
    assert np.abs(got - ref).max() / scale < tol


@pytest.mark.parametrize("kernel", ["harmonic", "log"])
def test_all_translations_vs_direct(kernel):
    p = 14
    xs = _cluster(40, 0.1 + 0.2j, 0.1)
    qs = RNG.normal(size=40) + 1j * RNG.normal(size=40)
    zt = _cluster(25, 2.0 - 1.5j, 0.08)
    ref = _direct(zt, xs, qs, kernel)
    reval = (lambda v: v.real) if kernel == "log" else (lambda v: v)

    a = E.p2m_single(jnp.asarray(xs), jnp.asarray(qs),
                     jnp.asarray(0.1 + 0.2j), p, kernel)
    # M2M up, M2L across, L2L down
    mm = jnp.asarray(E.m2m_matrix(p))
    hm = jnp.asarray(E.m2l_matrix(p))
    lm = jnp.asarray(E.l2l_matrix(p))
    a2 = E.m2m_apply(a, jnp.asarray((0.1 + 0.2j) - (0.15 + 0.15j)), mm)
    b = E.m2l_apply(a2, jnp.asarray((2.05 - 1.45j) - (0.15 + 0.15j)), hm)
    c = E.l2l_apply(b, jnp.asarray((2.0 - 1.5j) - (2.05 - 1.45j)), lm)
    got = np.asarray(E.eval_local(c, 2.0 - 1.5j, jnp.asarray(zt)))
    err = np.abs(reval(got) - reval(ref)).max() / np.abs(reval(ref)).max()
    assert err < 1e-5


@pytest.mark.parametrize("kernel", ["harmonic", "log"])
@pytest.mark.parametrize("p", [3, 9, 17])
def test_horner_equals_matrix_forms(kernel, p):
    a = (RNG.normal(size=(6, p + 1)) + 1j * RNG.normal(size=(6, p + 1)))
    if kernel == "harmonic":
        a[:, 0] = 0
    a = jnp.asarray(a)
    t = jnp.asarray(RNG.normal(size=6) + 1j * RNG.normal(size=6))
    np.testing.assert_allclose(
        np.asarray(E.m2m_horner(a, t)),
        np.asarray(E.m2m_apply(a, t, jnp.asarray(E.m2m_matrix(p)))),
        rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(E.l2l_horner(a, t)),
        np.asarray(E.l2l_apply(a, t, jnp.asarray(E.l2l_matrix(p)))),
        rtol=1e-10, atol=1e-12)
    r = t + 4.0  # well separated
    np.testing.assert_allclose(
        np.asarray(E.m2l_horner(a, r)),
        np.asarray(E.m2l_apply(a, r, jnp.asarray(E.m2l_matrix(p)))),
        rtol=1e-9, atol=1e-11)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 20), st.floats(-1, 1), st.floats(-1, 1),
       st.floats(-1, 1), st.floats(-1, 1))
def test_m2m_composition_property(p, a1, b1, a2, b2):
    """Shifting z0->z1->z2 equals shifting z0->z2 (pure group property)."""
    t1 = complex(a1, b1) + 0.31
    t2 = complex(a2, b2) + 0.17j
    coeffs = RNG.normal(size=p + 1) + 1j * RNG.normal(size=p + 1)
    mm = jnp.asarray(E.m2m_matrix(p))
    one = E.m2m_apply(jnp.asarray(coeffs), jnp.asarray(t1 + t2), mm)
    two = E.m2m_apply(E.m2m_apply(jnp.asarray(coeffs), jnp.asarray(t1), mm),
                      jnp.asarray(t2), mm)
    np.testing.assert_allclose(np.asarray(one), np.asarray(two),
                               rtol=1e-7, atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 20))
def test_l2l_composition_property(p):
    s1, s2 = 0.3 - 0.1j, -0.2 + 0.25j
    coeffs = jnp.asarray(RNG.normal(size=p + 1) + 1j * RNG.normal(size=p + 1))
    lm = jnp.asarray(E.l2l_matrix(p))
    one = E.l2l_apply(coeffs, jnp.asarray(s1 + s2), lm)
    two = E.l2l_apply(E.l2l_apply(coeffs, jnp.asarray(s1), lm),
                      jnp.asarray(s2), lm)
    np.testing.assert_allclose(np.asarray(one), np.asarray(two),
                               rtol=1e-7, atol=1e-9)

"""FmmSolver front-end: plan caching, backend dispatch, batched
evaluation vs a per-problem loop, and cap autotuning."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import FmmConfig, fmm_potential
from repro.data.synthetic import particles
from repro.solver import (FmmSolver, available_backends, get_backend,
                          probe_caps, tune_caps)

CFG64 = FmmConfig(n=256, nlevels=2, p=10, dtype="f64")


def _batch(b, n, dist="uniform", seed0=0):
    zs, qs = [], []
    for i in range(b):
        z, q = particles(dist, n, seed0 + i)
        zs.append(np.asarray(z))
        qs.append(np.asarray(q))
    return jnp.asarray(np.stack(zs)), jnp.asarray(np.stack(qs))


# ---------------------------------------------------------------------------
# single-problem apply + plan cache
# ---------------------------------------------------------------------------

def test_apply_matches_fmm_potential():
    z, q = particles("normal", CFG64.n, 3)
    z, q = jnp.asarray(z), jnp.asarray(q)
    solver = FmmSolver.build(CFG64, "reference")
    np.testing.assert_allclose(np.asarray(solver.apply(z, q)),
                               np.asarray(fmm_potential(z, q, CFG64)),
                               rtol=1e-12, atol=1e-12)


def test_build_is_cached_per_config_and_backend():
    a = FmmSolver.build(CFG64, "reference")
    assert FmmSolver.build(CFG64, "reference") is a
    # "auto" shares the cache entry of whatever backend it resolves to
    # (reference on CPU: interpret-mode pallas is not a fast path)
    resolved = get_backend("auto", CFG64).name
    assert (FmmSolver.build(CFG64, "auto") is a) == (resolved == "reference")
    import dataclasses
    other = dataclasses.replace(CFG64, p=CFG64.p + 1)
    assert FmmSolver.build(other, "reference") is not a


def test_apply_checked_raises_on_overflow():
    import dataclasses
    tiny = dataclasses.replace(CFG64, strong_cap=2, weak_cap=2)
    z, q = particles("normal", CFG64.n, 5)
    z, q = jnp.asarray(z), jnp.asarray(q)
    solver = FmmSolver(tiny, "reference")
    with pytest.raises(RuntimeError, match="overflow"):
        solver.apply_checked(z, q)
    # ...while on an in-cap input it returns the plain-apply answer
    ok = FmmSolver.build(CFG64, "reference")
    np.testing.assert_array_equal(np.asarray(ok.apply_checked(z, q)),
                                  np.asarray(ok.apply(z, q)))


def test_unknown_backend_raises():
    with pytest.raises(KeyError):
        FmmSolver.build(CFG64, "cuda")
    assert set(available_backends()) >= {"reference", "pallas", "auto"}


def test_pallas_backend_supports_log_kernel(monkeypatch):
    cfg = FmmConfig(n=64, nlevels=1, p=6, kernel="log", dtype="f64")
    assert get_backend("pallas", cfg).supports(cfg)
    # "auto" must dispatch log-kernel configs somewhere that supports them
    assert get_backend("auto", cfg).supports(cfg)
    # ...and on a TPU platform it picks pallas (no silent reference
    # fallback for log configs)
    from repro.solver import backends
    monkeypatch.setattr(backends, "_platform", lambda: "tpu")
    assert get_backend("auto", cfg).name == "pallas"
    monkeypatch.setattr(backends, "_platform", lambda: "cpu")
    assert get_backend("auto", cfg).name == "reference"


# ---------------------------------------------------------------------------
# batched evaluation
# ---------------------------------------------------------------------------

def test_apply_batched_matches_per_problem_loop():
    B = 8
    solver = FmmSolver.build(CFG64, "reference")
    zb, qb = _batch(B, CFG64.n)
    got = np.asarray(solver.apply_batched(zb, qb))
    ref = np.stack([np.asarray(solver.apply(zb[i], qb[i]))
                    for i in range(B)])
    scale = np.abs(ref).max()
    assert np.abs(got - ref).max() / scale < 1e-6
    # and each row is a genuinely different problem
    assert np.abs(got[0] - got[1]).max() / scale > 1e-3


def test_apply_batched_shape_validation():
    solver = FmmSolver.build(CFG64, "reference")
    z, q = _batch(2, CFG64.n)
    with pytest.raises(ValueError):
        solver.apply_batched(z[0], q[0])
    with pytest.raises(ValueError):
        solver.apply_batched(z[:, :100], q[:, :100])


def test_apply_batched_pallas_backend_dispatches_natively():
    """The pallas kernels are batch-native (custom batching rules lower
    jax.vmap onto batch-major grids): the batched entry serves through
    the pallas hooks — no downgrade, no warning — and agrees with the
    reference batched answer."""
    import warnings as W
    cfg = FmmConfig(n=256, nlevels=2, p=8, dtype="f32",
                    strong_cap=40, weak_cap=64)
    zb, qb = _batch(2, cfg.n, dist="normal")
    solver = FmmSolver.build(cfg, "pallas")
    assert solver.dispatched["apply_batched"] == "pallas"
    with W.catch_warnings():
        W.simplefilter("error")
        got = np.asarray(solver.apply_batched(zb, qb))
    ref = np.asarray(FmmSolver.build(cfg, "reference").apply_batched(zb, qb))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_dispatched_backend_is_recorded_and_fallback_warns_once():
    """The solver records what each entry point actually runs — a
    batched_dispatch="fallback" backend downgrades the batched entry to
    the reference sweeps — and warns exactly once per solver about the
    downgrade. The pallas backend is batch-native and never downgrades."""
    import warnings as W
    from repro.solver.backends import (Backend, _REGISTRY, get_backend,
                                       register_backend)
    cfg = FmmConfig(n=128, nlevels=1, p=6, dtype="f64",
                    strong_cap=40, weak_cap=64)
    pallas = get_backend("pallas", cfg)
    assert pallas.batched_dispatch == "native"
    assert FmmSolver(cfg, "pallas").dispatched == {
        "apply": "pallas", "apply_batched": "pallas"}
    # a third-party backend without batching rules declares "fallback"
    register_backend(Backend(name="unbatchable",
                             batched_dispatch="fallback"))
    try:
        solver = FmmSolver(cfg, "unbatchable")
        assert solver.dispatched == {"apply": "unbatchable",
                                     "apply_batched": "reference"}
        zb, qb = _batch(2, cfg.n)
        with pytest.warns(RuntimeWarning, match="apply_batched dispatches"):
            solver.apply_batched(zb, qb)
        with W.catch_warnings():        # one-time: silent on repeat
            W.simplefilter("error")
            solver.apply_batched(zb, qb)
    finally:
        _REGISTRY.pop("unbatchable", None)
    ref = FmmSolver(cfg, "reference")
    assert ref.dispatched == {"apply": "reference",
                              "apply_batched": "reference"}


def test_backend_rejects_unknown_batched_dispatch():
    from repro.solver.backends import Backend
    with pytest.raises(ValueError, match="batched_dispatch"):
        Backend(name="bogus", batched_dispatch="maybe")


def test_tune_result_records_dispatched_backends():
    solver = FmmSolver.build(CFG64, "reference")
    z, q = particles("normal", CFG64.n, 5)
    tuned = solver.tune(jnp.asarray(z), jnp.asarray(q), tiles=False)
    assert dict(tuned.tune_result.dispatched) == {
        "apply": "reference", "apply_batched": "reference"}


# ---------------------------------------------------------------------------
# backend agreement: pallas (interpret) vs reference
# ---------------------------------------------------------------------------

def test_pallas_and_reference_backends_agree():
    cfg = FmmConfig(n=512, nlevels=2, p=8, dtype="f32",
                    strong_cap=40, weak_cap=64)
    z, q = particles("normal", cfg.n, 11)
    z, q = jnp.asarray(z), jnp.asarray(q)
    ref = np.asarray(FmmSolver.build(cfg, "reference").apply(z, q))
    got = np.asarray(FmmSolver.build(cfg, "pallas").apply(z, q))
    scale = np.abs(ref).max()
    assert np.abs(got - ref).max() / scale < 5e-4  # f32 kernel tolerance


# ---------------------------------------------------------------------------
# cap autotuning
# ---------------------------------------------------------------------------

def test_tune_returns_overflow_free_shrunk_caps():
    solver = FmmSolver.build(CFG64, "reference")
    zb, qb = _batch(4, CFG64.n)
    tuned = solver.tune(zb, qb)
    res = tuned.tune_result
    assert res.stats["overflow"] == 0
    assert res.trials[-1][2] == 0
    # generous seed caps (48/192) shrink to the workload
    assert tuned.cfg.strong_cap <= CFG64.strong_cap
    assert tuned.cfg.weak_cap <= CFG64.weak_cap
    assert tuned.cfg.strong_cap >= res.stats["strong_max"]
    assert tuned.cfg.weak_cap >= res.stats["weak_max"]
    # tuned solver computes the same answer
    np.testing.assert_allclose(np.asarray(tuned.apply(zb[0], qb[0])),
                               np.asarray(solver.apply(zb[0], qb[0])),
                               rtol=1e-10, atol=1e-10)


def test_tune_grows_undersized_caps():
    import dataclasses
    tiny = dataclasses.replace(CFG64, strong_cap=2, weak_cap=2)
    z, q = particles("normal", CFG64.n, 5)
    z, q = jnp.asarray(z), jnp.asarray(q)
    assert probe_caps(z, q, tiny)[0] > 0  # genuinely undersized
    res = tune_caps(z, q, tiny)
    assert res.stats["overflow"] == 0
    assert res.cfg.strong_cap > tiny.strong_cap
    # growth trials were recorded before the overflow-free shrink
    assert any(t[2] > 0 for t in res.trials)


def test_tune_unsorts_margin_validation():
    with pytest.raises(ValueError):
        tune_caps(jnp.zeros(4), None, CFG64, margin=0.5)


# ---------------------------------------------------------------------------
# tile autotuning (tile_boxes / stage_width)
# ---------------------------------------------------------------------------

def test_tune_returns_tile_settings_alongside_caps():
    """Off-TPU (no meaningful timings) the lane heuristic picks the tile;
    the result still carries tile settings next to the caps."""
    solver = FmmSolver.build(CFG64, "reference")
    z, q = particles("normal", CFG64.n, 5)
    tuned = solver.tune(jnp.asarray(z), jnp.asarray(q))
    res = tuned.tune_result
    assert res.tile_trials, "tune() must report tile trials"
    assert tuned.cfg.tile_boxes == res.tile_trials[-1][0]
    assert 1 <= tuned.cfg.tile_boxes <= CFG64.nboxes
    assert tuned.cfg.stage_width >= 1
    # tiles can be switched off
    res_off = solver.tune(jnp.asarray(z), jnp.asarray(q),
                          tiles=False).tune_result
    assert res_off.tile_trials == ()


def test_tune_tiles_timing_sweep_picks_fastest():
    """With an injected timer (the TPU measurement path), tune() sweeps
    tile_boxes then stage_width and picks the fastest combination."""
    measured = []

    def timer(z, q, cfg):
        measured.append((cfg.tile_boxes, cfg.stage_width))
        # fastest at tile_boxes=4, stage_width=2
        return (abs(cfg.tile_boxes - 4) + 1) * (1.5 - 0.5 *
                                                (cfg.stage_width == 2))

    solver = FmmSolver.build(CFG64, "reference")
    z, q = particles("normal", CFG64.n, 5)
    tuned = solver.tune(jnp.asarray(z), jnp.asarray(q), tile_timer=timer)
    assert tuned.cfg.tile_boxes == 4
    assert tuned.cfg.stage_width == 2
    assert len(tuned.tune_result.tile_trials) == len(measured)
    # the tile sweep ran at stage_width=1 over pow-2 candidates <= nboxes
    assert {t for t, s in measured if s == 1} == {1, 2, 4, 8, 16}


def test_tune_tiles_batched_sample_times_batched_path():
    """A (B, N) sample keeps its batch axis through the tile-timing
    sweep on a backend that serves batches through its own hooks
    (batched_dispatch != "fallback"): the measured program is the
    vmapped batch-major pipeline, i.e. what apply_batched runs."""
    shapes = []

    def timer(z, q, cfg):
        shapes.append(z.shape)
        return float(cfg.tile_boxes)

    solver = FmmSolver.build(CFG64, "reference")
    zb, qb = _batch(3, CFG64.n)
    solver.tune(zb, qb, tile_timer=timer)
    assert shapes and all(s == (3, CFG64.n) for s in shapes)


def test_tile_candidates_respect_fused_eval_vmem_budget():
    """Large-leaf configs must cap tile_boxes: the fused evaluation
    kernel's VMEM working set scales with tile_boxes * n_pad."""
    from repro.solver.autotune import eval_fused_vmem_bytes, tile_candidates
    big_leaves = FmmConfig(n=1 << 15, nlevels=2, p=10, dtype="f32")
    tight = 1 << 20
    cands = tile_candidates(big_leaves, vmem_budget=tight)
    assert cands and max(cands) < 16
    assert all(eval_fused_vmem_bytes(big_leaves, tile_boxes=t) <= tight
               for t in cands)
    # the default budget always leaves at least one candidate
    assert tile_candidates(big_leaves)
    # small-leaf configs keep the full pow-2 sweep
    assert tile_candidates(CFG64) == [1, 2, 4, 8, 16]


def test_solver_stats_reports_overflow_scalar():
    z, q = particles("uniform", CFG64.n, 1)
    stats = FmmSolver.build(CFG64, "reference").stats(jnp.asarray(z),
                                                      jnp.asarray(q))
    assert stats["overflow"] == 0
    assert stats["p2p_pairs"] > 0


# ---------------------------------------------------------------------------
# plan refresh (time-stepping workloads)
# ---------------------------------------------------------------------------

def _perturbed(z, seed, eps=1e-4):
    rng = np.random.default_rng(seed)
    zd = np.asarray(z) + eps * (rng.normal(size=z.shape)
                                + 1j * rng.normal(size=z.shape))
    # clamp per component: complex np.clip compares lexicographically
    return jnp.asarray(np.clip(zd.real, 0, 1) + 1j * np.clip(zd.imag, 0, 1))


def test_refresh_plus_apply_plan_matches_apply():
    z, q = particles("normal", CFG64.n, 7)
    z, q = jnp.asarray(z), jnp.asarray(q)
    solver = FmmSolver.build(CFG64, "reference")
    plan = solver.refresh(z, q)
    np.testing.assert_allclose(np.asarray(solver.apply_plan(plan)),
                               np.asarray(solver.apply(z, q)),
                               rtol=1e-12, atol=1e-12)


def test_refresh_does_not_retrace_on_perturbed_positions():
    """The time-stepping contract: after the first step, refreshing moved
    particles reuses the compiled build/evaluate programs (trace-count
    asserted; a re-trace would pay compilation per step)."""
    z, q = particles("uniform", CFG64.n, 8)
    z, q = jnp.asarray(z), jnp.asarray(q)
    solver = FmmSolver(CFG64, "reference")   # fresh instance: clean counters
    for step in range(3):
        plan = solver.refresh(_perturbed(z, step), q)
        phi = solver.apply_plan(plan)
        assert phi.shape == (CFG64.n,)
        assert int(plan.conn.overflow) == 0
    assert solver.trace_counts == {"build": 1, "evaluate": 1}


def test_refresh_validates_shape():
    solver = FmmSolver.build(CFG64, "reference")
    z, q = particles("uniform", CFG64.n, 9)
    with pytest.raises(ValueError, match="refresh"):
        solver.refresh(jnp.asarray(z)[: CFG64.n // 2],
                       jnp.asarray(q)[: CFG64.n // 2])


def test_refresh_overflow_scalar_monitors_cap_drift():
    """plan.conn.overflow is the cheap per-step cap monitor: a config
    whose caps are too small for the refreshed layout must flag it."""
    z, q = particles("normal", 256, 10)
    tight = dataclasses_replace_caps(CFG64, strong_cap=2)
    solver = FmmSolver.build(tight, "reference")
    plan = solver.refresh(jnp.asarray(z), jnp.asarray(q))
    assert int(plan.conn.overflow) > 0


def dataclasses_replace_caps(cfg, **kw):
    import dataclasses
    kw.setdefault("weak_cap", 0)
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# argument validation: typed errors on the unbatched entry points
# ---------------------------------------------------------------------------

def test_apply_rejects_real_positions_with_typed_error():
    from repro.errors import DTypeError, ValidationError
    solver = FmmSolver.build(CFG64, "reference")
    z, q = particles("uniform", CFG64.n, 2)
    with pytest.raises(DTypeError, match="complex-vs-real"):
        solver.apply(jnp.real(jnp.asarray(z)), jnp.asarray(q))
    with pytest.raises(DTypeError, match="complex"):
        solver.apply(jnp.asarray(z), jnp.real(jnp.asarray(q)))
    # the taxonomy keeps legacy except-clauses working
    assert issubclass(DTypeError, (TypeError, ValidationError, ValueError))


def test_apply_rejects_narrower_dtype_than_config():
    from repro.errors import DTypeError
    solver = FmmSolver.build(CFG64, "reference")   # f64 config
    z, q = particles("uniform", CFG64.n, 2)
    z32 = jnp.asarray(np.asarray(z), jnp.complex64)
    q32 = jnp.asarray(np.asarray(q), jnp.complex64)
    with pytest.raises(DTypeError, match="precision"):
        solver.apply(z32, q32)
    # ...but higher-precision input into an f32 config is fine (it is
    # what the x64-enabled test suite does everywhere)
    f32 = FmmConfig(n=256, nlevels=2, p=6, dtype="f32")
    assert FmmSolver.build(f32, "reference").apply(
        jnp.asarray(z), jnp.asarray(q)).shape == (f32.n,)


def test_apply_and_refresh_reject_mismatched_lengths():
    from repro.errors import ShapeError
    solver = FmmSolver.build(CFG64, "reference")
    z, q = particles("uniform", CFG64.n, 2)
    with pytest.raises(ShapeError, match="apply wants"):
        solver.apply(jnp.asarray(z), jnp.asarray(q)[:-3])
    with pytest.raises(ShapeError, match="refresh wants"):
        solver.refresh(jnp.asarray(z)[None], jnp.asarray(q)[None])


# ---------------------------------------------------------------------------
# bounded plan cache: LRU eviction + observability
# ---------------------------------------------------------------------------

def test_cache_info_counts_hits_misses_and_evictions(monkeypatch):
    import dataclasses
    from repro.solver import solver as solver_mod
    FmmSolver.cache_clear()
    monkeypatch.setattr(solver_mod, "_CACHE_MAX", 2)
    cfgs = [dataclasses.replace(CFG64, p=p) for p in (3, 4, 5)]
    a = FmmSolver.build(cfgs[0], "reference")
    assert FmmSolver.build(cfgs[0], "reference") is a          # hit
    FmmSolver.build(cfgs[1], "reference")
    FmmSolver.build(cfgs[2], "reference")                      # evicts a
    info = FmmSolver.cache_info()
    assert info.hits == 1 and info.misses == 3
    assert info.evictions == 1 and info.currsize == 2 == info.maxsize
    # the evicted solver re-builds as a fresh instance (old one stays
    # usable by existing holders)
    assert FmmSolver.build(cfgs[0], "reference") is not a
    assert FmmSolver.cache_info().misses == 4
    FmmSolver.cache_clear()
    zeroed = FmmSolver.cache_info()
    assert (zeroed.hits, zeroed.misses, zeroed.evictions,
            zeroed.currsize) == (0, 0, 0, 0)


def test_eviction_releases_compiled_programs(monkeypatch):
    """Regression: LRU eviction under _CACHE_MAX pressure must release
    the evicted solver's compiled programs — health twins included —
    instead of stranding them behind jit's trace cache; and
    cache_clear() must reset them too."""
    import dataclasses
    from repro.solver import solver as solver_mod
    FmmSolver.cache_clear()
    monkeypatch.setattr(solver_mod, "_CACHE_MAX", 1)

    cfg_a = dataclasses.replace(CFG64, p=3)
    cfg_b = dataclasses.replace(CFG64, p=4)
    z, q = particles("uniform", CFG64.n, 1)
    z, q = jnp.asarray(z), jnp.asarray(q)

    a = FmmSolver.build(cfg_a, "reference")
    a.apply(z, q)                      # plain program
    a.apply_with_health(z, q)          # health twin
    assert a._compiled_program_count() >= 2

    FmmSolver.build(cfg_b, "reference")    # evicts a
    assert FmmSolver.cache_info().evictions == 1
    assert a._compiled_program_count() == 0, \
        "eviction stranded compiled programs (health twin leak)"

    # the evicted instance stays usable — the next call re-traces
    np.testing.assert_allclose(np.asarray(a.apply(z, q)),
                               np.asarray(fmm_potential(z, q, cfg_a)),
                               rtol=1e-12, atol=1e-12)
    assert a._compiled_program_count() == 1

    # cache_clear releases programs of everything still cached
    b = FmmSolver.build(cfg_b, "reference")
    b.apply(z, q)
    assert b._compiled_program_count() >= 1
    FmmSolver.cache_clear()
    assert b._compiled_program_count() == 0
    assert a._compiled_program_count() == 1    # uncached holder untouched

"""End-to-end FMM accuracy vs the O(N^2) oracle (paper eq. (5.3)) and the
p -> tolerance law; f32 and f64; both translation backends; the adaptive
P2L/M2P optimization on and off."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (FmmConfig, direct_potential, fmm_potential,
                        rel_error_inf)
from repro.data.synthetic import particles


def _run(n=2048, levels=3, p=12, dist="uniform", seed=0, **kw):
    z, q = particles(dist, n, seed)
    cfg = FmmConfig(n=n, nlevels=levels, p=p, **kw)
    phi = fmm_potential(jnp.asarray(z), jnp.asarray(q), cfg)
    ref = direct_potential(jnp.asarray(z), jnp.asarray(z), jnp.asarray(q))
    return rel_error_inf(np.asarray(phi), np.asarray(ref))


@pytest.mark.parametrize("dist", ["uniform", "normal", "layer"])
def test_accuracy_three_distributions(dist):
    assert _run(dist=dist, p=16, dtype="f64") < 2e-5  # eccentric "layer" boxes
    # converge slightly slower (half-diagonal radii); cf. paper Fig 5.8-5.9


def test_accuracy_paper_p17_tolerance():
    """Paper §5.1: p=17 -> TOL ~ 1e-6 at theta = 1/2."""
    assert _run(p=17, dtype="f64") < 2e-6


def test_error_decays_with_p():
    errs = [_run(p=p, dtype="f64") for p in (4, 8, 12, 16)]
    assert all(a > b for a, b in zip(errs, errs[1:]))
    # contraction per term ~ theta/(1+theta) = 1/3; allow slack
    assert errs[-1] < errs[0] * 1e-4


def test_f32_reaches_single_precision_floor():
    err = _run(p=17, dtype="f32")
    assert err < 5e-4  # f32 floor amplified by cancellation; see DESIGN §2


def test_horner_equals_mxu_pipeline():
    e1 = _run(p=10, dtype="f64", translations="mxu")
    e2 = _run(p=10, dtype="f64", translations="horner")
    assert abs(e1 - e2) / e1 < 1e-6


def test_p2l_m2p_optimization_preserves_answer():
    e_on = _run(p=12, dist="normal", dtype="f64", use_p2l_m2p=True)
    e_off = _run(p=12, dist="normal", dtype="f64", use_p2l_m2p=False)
    assert e_on < 5e-4 and e_off < 5e-4


def test_log_kernel():
    z, q = particles("uniform", 1024, 3)
    cfg = FmmConfig(n=1024, nlevels=2, p=14, kernel="log", dtype="f64")
    phi = fmm_potential(jnp.asarray(z), jnp.asarray(q), cfg)
    ref = direct_potential(jnp.asarray(z), jnp.asarray(z), jnp.asarray(q),
                           kernel="log")
    err = rel_error_inf(np.real(np.asarray(phi)), np.real(np.asarray(ref)))
    assert err < 3e-5


def test_single_level_tree():
    """nlevels=0 degenerates to direct evaluation through P2P."""
    z, q = particles("uniform", 128, 4)
    cfg = FmmConfig(n=128, nlevels=0, p=4, dtype="f64")
    phi = fmm_potential(jnp.asarray(z), jnp.asarray(q), cfg)
    ref = direct_potential(jnp.asarray(z), jnp.asarray(z), jnp.asarray(q))
    assert rel_error_inf(np.asarray(phi), np.asarray(ref)) < 1e-12


def test_potential_is_permutation_equivariant():
    z, q = particles("uniform", 512, 5)
    cfg = FmmConfig(n=512, nlevels=2, p=12, dtype="f64")
    phi = np.asarray(fmm_potential(jnp.asarray(z), jnp.asarray(q), cfg))
    perm = np.random.default_rng(0).permutation(512)
    phi_p = np.asarray(fmm_potential(jnp.asarray(np.asarray(z)[perm]),
                                     jnp.asarray(np.asarray(q)[perm]), cfg))
    np.testing.assert_allclose(phi_p, phi[perm], rtol=1e-9, atol=1e-11)

"""Serving plane: bucket lattice, padding exactness (property-based
bucket-boundary parity), keyed executable cache, admission control,
degradation ladder, and the straggler wiring."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_fallback import given, settings, st

from repro.configs.fmm2d import fmm_config
from repro.core import FmmConfig
from repro.data.synthetic import particles, ragged_requests
from repro.errors import ShapeError
from repro.launch.runtime import StragglerMonitor
from repro.serve import (BucketLattice, PlanCache, Request, ServePlane,
                         pad_problem, unpad)
from repro.solver import FmmSolver


def _cheap_cfg(n: int) -> FmmConfig:
    """Small-p f64 config for fast serving tests (compile cost, not
    accuracy, dominates these)."""
    return dataclasses.replace(fmm_config(n, p=6, dtype="f64"),
                               strong_cap=48, weak_cap=96)


def _plane(**kw) -> ServePlane:
    kw.setdefault("backend", "reference")
    kw.setdefault("cfg_factory", _cheap_cfg)
    kw.setdefault("max_batch", 4)
    kw.setdefault("direct_max", 512)
    return ServePlane(BucketLattice(sizes=(32, 64, 128)), **kw)


def _mk(n, seed=0):
    z, q = particles("uniform", n, seed)
    return np.asarray(z), np.asarray(q)


# ---------------------------------------------------------------------------
# bucket lattice
# ---------------------------------------------------------------------------

def test_lattice_geometry_and_lookup():
    lat = BucketLattice.geometric(64, 1024, factor=2.0)
    assert lat.sizes == (64, 128, 256, 512, 1024)
    assert lat.bucket_for(1) == 64
    assert lat.bucket_for(64) == 64
    assert lat.bucket_for(65) == 128
    assert lat.bucket_for(1024) == 1024
    assert lat.bucket_for(1025) is None
    assert lat.next_larger(64) == 128
    assert lat.next_larger(1024) is None
    with pytest.raises(ValueError):
        lat.bucket_for(0)
    with pytest.raises(ValueError):
        BucketLattice(sizes=(64, 64))
    with pytest.raises(ValueError):
        BucketLattice.geometric(64, 128, factor=1.0)


# ---------------------------------------------------------------------------
# padding: exactness properties
# ---------------------------------------------------------------------------

def test_pad_preserves_real_rows_bit_exactly():
    z, q = _mk(50)
    zp, qp = pad_problem(z, q, 64)
    assert zp.shape == qp.shape == (64,)
    np.testing.assert_array_equal(zp[:50], z)
    np.testing.assert_array_equal(qp[:50], q)
    np.testing.assert_array_equal(qp[50:], np.zeros(14, qp.dtype))
    # deterministic in (seed, size, n)
    zp2, _ = pad_problem(z, q, 64)
    np.testing.assert_array_equal(zp, zp2)
    with pytest.raises(ShapeError):
        pad_problem(z, q, 32)


def test_pad_never_coincides_even_after_f32_narrowing():
    z, q = _mk(40, seed=2)
    zp, _ = pad_problem(z, q, 256, dtype=np.complex64)
    z32 = zp.astype(np.complex64)
    assert np.unique(z32).size == z32.size, \
        "padding collided with a real point (or itself) after f32 cast"


def test_pad_terminates_on_degenerate_input():
    # all-coincident input: zero-width bbox must widen, not spin forever
    z = np.full(8, 0.25 + 0.25j)
    q = np.ones(8) + 0j
    zp, qp = pad_problem(z, q, 32)
    assert np.unique(zp[8:]).size == 24
    assert not np.isin(zp[8:], z).any()


@settings(max_examples=5, deadline=None)
@given(st.integers(-1, 1), st.integers(0, 3))
def test_bucket_boundary_parity(delta, seed):
    """Property (ISSUE satellite): padded bucket evaluation matches the
    unpadded apply at <= 1e-10 rel in f64, for N exactly on a bucket
    edge and edge +- 1, with zero-charge tail rows. The two runs use
    different trees (rank-median splits see the tail), so they agree to
    truncation error — p=30 puts that below the 1e-10 gate."""
    edge = 64
    n = edge + delta
    z, q = _mk(n, seed=seed)
    zj, qj = jnp.asarray(z), jnp.asarray(q)

    cfg_exact = fmm_config(n, p=30, dtype="f64")
    phi_ref = np.asarray(FmmSolver.build(cfg_exact, "reference")
                         .apply(zj, qj))

    bucket = BucketLattice(sizes=(edge, 2 * edge)).bucket_for(n)
    cfg_pad = fmm_config(bucket, p=30, dtype="f64")
    zp, qp = pad_problem(z, q, bucket, dtype=cfg_pad.complex_dtype)
    phi_pad = unpad(np.asarray(
        FmmSolver.build(cfg_pad, "reference")
        .apply(jnp.asarray(zp), jnp.asarray(qp))), n)

    scale = np.abs(phi_ref).max()
    err = np.abs(phi_pad - phi_ref).max() / scale
    assert err <= 1e-10, (n, bucket, err)


# ---------------------------------------------------------------------------
# keyed executable cache
# ---------------------------------------------------------------------------

def test_plan_cache_counters_eviction_and_identity():
    cache = PlanCache(_cheap_cfg, "reference", max_entries=2)
    a, hit = cache.get(32, 1)
    assert not hit
    b, hit = cache.get(32, 1)
    assert hit and b is a, "a cache hit must return the same guarded " \
        "solver (promoted caps stick to the shape class)"
    cache.get(64, 1)
    cache.get(128, 1)      # evicts (32, 1) — LRU
    info = cache.info()
    assert info[32].hits == 1 and info[32].misses == 1
    assert info[32].evictions == 1
    assert len(cache) == 2
    c, hit = cache.get(32, 1)
    assert not hit and c is not a


def test_plan_cache_warm_precompiles():
    cache = PlanCache(_cheap_cfg, "reference", max_entries=4)
    warmed = cache.warm_all([32], [1, 2])
    assert warmed == [(32, 1), (32, 2)]
    entry = cache.entry(32, 2)
    assert entry is not None
    assert entry.solver._compiled_program_count() >= 1, \
        "warm() must actually compile the batched health twin"


# ---------------------------------------------------------------------------
# the plane: admission, dispatch, degradation
# ---------------------------------------------------------------------------

def test_serve_mixed_wave_statuses_and_parity():
    plane = _plane()
    z1, q1 = _mk(30, 1)
    z2, q2 = _mk(64, 2)
    zbig, qbig = _mk(200, 3)          # oversize for lattice -> direct
    zpoison, qpoison = _mk(20, 4)
    qpoison = qpoison.copy()
    qpoison[0] = np.nan
    results = plane.serve([
        Request(z1, q1), Request(z2, q2), Request(zbig, qbig),
        Request(zpoison, qpoison),
        Request(np.linspace(0, 1, 16), np.ones(16) + 0j),   # real z
        Request(*_mk(2000, 5)),                             # way oversize
    ])
    stat = [r.report.status for r in results]
    assert stat[0] == stat[1] == "ok"
    assert stat[2] == "degraded" and results[2].report.backend == "direct"
    assert stat[3] == "rejected" and \
        results[3].report.error == "NonFiniteInputError"
    assert stat[4] == "rejected" and results[4].report.error == "DTypeError"
    assert stat[5] == "rejected" and \
        results[5].report.error == "OversizedRequestError"
    # same-bucket requests share one dispatch; the answers are real
    from repro.core.direct import direct_potential
    for res, (z, q) in zip(results[:3], [(z1, q1), (z2, q2), (zbig, qbig)]):
        ref = np.asarray(direct_potential(jnp.asarray(z), jnp.asarray(z),
                                          jnp.asarray(q)))
        err = np.abs(res.phi - ref).max() / np.abs(ref).max()
        assert err < 1e-3, (res.report.rid, err)
    stats = plane.stats()
    assert stats["rejected"] == 3 and stats["requests"] == 6
    assert results[0].report.summary().startswith("[serve:req0]")


def test_serve_consumes_ragged_generator():
    plane = _plane()
    reqs = [Request(z, q) for _, z, q, _ in
            ragged_requests(6, seed=5, median_n=40, sigma=0.4, n_max=100)]
    results = plane.serve(reqs)
    assert all(r.report.status in ("ok", "recovered", "degraded")
               for r in results)
    assert all(np.all(np.isfinite(r.phi)) for r in results)


def test_serve_deadline_sheds_typed():
    # a clock that jumps far past any budget between admission and
    # dispatch: every request must shed as DeadlineExceededError
    t = {"now": 0.0}

    def clock():
        t["now"] += 10.0
        return t["now"]

    plane = _plane(default_deadline_s=1.0, clock=clock, sleep=lambda s: None)
    results = plane.serve([Request(*_mk(20, i)) for i in range(3)])
    for phi, rep in results:
        assert phi is None
        assert rep.status == "rejected"
        assert rep.error == "DeadlineExceededError"
        assert rep.deadline_exceeded
    assert plane.stats()["rejected"] == 3


def test_straggler_monitor_flags_slow_dispatch():
    """Satellite: the launch runtime's StragglerMonitor is the serving
    plane's slow-request detector — a spiked dispatch must surface as
    slow=True on its ServeReport."""
    from repro.testing.serve_faults import latency_spike

    # threshold 10x: immune to ordinary CPU timing jitter, but the
    # injected 0.5s spike is ~100x the few-ms median
    monitor = StragglerMonitor(window=16, threshold=10.0, warmup=1)
    plane = _plane(max_batch=1, monitor=monitor)
    z, q = _mk(20, 7)
    plane.submit(z, q)                      # compile (warmup-excluded)
    for i in range(6):                      # build the median history
        plane.submit(*_mk(20, 10 + i))
    assert plane.stats()["slow_dispatches"] == 0
    with latency_spike(every=1, spike_s=0.5):
        phi, rep = plane.submit(*_mk(20, 99))
    assert rep.slow, "spiked dispatch not flagged by the monitor"
    assert plane.stats()["slow_dispatches"] == 1
    assert monitor.slow_steps, "monitor did not record the spike"

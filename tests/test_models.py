"""Per-arch smoke tests (reduced same-family configs) + cache-path
correctness: prefill+decode logits must match the full forward pass."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import (decode_step, forward_hidden, forward_loss,
                          init_cache, lm, make_params, prefill)

B, S = 2, 16


def _batch(cfg, seed=0, seq=S):
    dc = DataConfig(vocab=cfg.vocab, batch=B, seq=seq, seed=seed)
    return lm_batch(dc, 0, cfg)


@pytest.fixture(scope="module")
def arch_state(request):
    cfg = smoke_config(request.param)
    params = make_params(cfg, 0)
    return cfg, params


def pytest_generate_tests(metafunc):
    if "arch_state" in metafunc.fixturenames:
        metafunc.parametrize("arch_state", list(ARCH_NAMES), indirect=True)


def test_forward_and_grads(arch_state):
    cfg, params = arch_state
    batch = _batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: forward_loss(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss)), cfg.name
    g = jax.grad(lambda p: forward_loss(p, _batch(cfg), cfg)[0])(params)
    leaves = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in leaves), cfg.name
    # no dead parameters: the embedding and at least 90% of leaves get grads
    nonzero = sum(float(jnp.any(x != 0)) for x in leaves)
    assert nonzero >= 0.9 * len(leaves), f"{cfg.name}: dead grads"


def test_prefill_decode_matches_forward(arch_state):
    """Decode with a prefilled cache must reproduce teacher-forced logits."""
    cfg, params = arch_state
    full = _batch(cfg, seq=S + 1)
    prompt = {k: (v[:, :S] if k in ("tokens", "labels") else v)
              for k, v in full.items()}

    # reference: forward over S+1 tokens, logits at the last position
    h, _ = forward_hidden(params, full, cfg)
    un = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ref_logits = np.asarray(
        jnp.einsum("d,dv->v", h[0, -1].astype(jnp.float32),
                   un.astype(jnp.float32)))

    cache, _ = prefill(params, prompt, cfg,
                       max_len=S + 4 + cfg.n_img_tokens)
    pos = S + cfg.n_img_tokens if cfg.arch == "vlm" else S
    logits, _ = decode_step(params, cache,
                            full["tokens"][:, S:S + 1], jnp.int32(pos), cfg)
    got = np.asarray(logits)[0]
    scale = np.abs(ref_logits).max()
    np.testing.assert_allclose(got, ref_logits, atol=2e-3 * scale,
                               err_msg=cfg.name)


def test_abstract_params_match_real(arch_state):
    cfg, params = arch_state
    ab = lm.make_abstract_params(cfg)
    real_flat = jax.tree.leaves(params)
    ab_flat = jax.tree.leaves(ab)
    assert len(real_flat) == len(ab_flat)
    for r, a in zip(real_flat, ab_flat):
        assert r.shape == a.shape and r.dtype == a.dtype


def test_init_cache_structure(arch_state):
    cfg, params = arch_state
    cache = init_cache(cfg, B, 8 + cfg.n_img_tokens)
    prompt = _batch(cfg, seq=8)
    c2, _ = prefill(params, prompt, cfg, max_len=8 + cfg.n_img_tokens)
    s1 = jax.tree.structure(cache)
    s2 = jax.tree.structure(c2)
    assert s1 == s2, f"{cfg.name}: {s1} vs {s2}"
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(c2)):
        assert a.shape == b.shape, cfg.name


def test_full_configs_param_counts():
    expected = {
        "dbrx-132b": 132, "arctic-480b": 480, "jamba-1.5-large-398b": 398,
        "qwen1.5-0.5b": 0.5, "nemotron-4-340b": 340, "qwen2-72b": 72,
        "qwen3-0.6b": 0.6, "llava-next-mistral-7b": 7,
        "whisper-small": 0.24, "rwkv6-1.6b": 1.6,
    }
    for name, bn in expected.items():
        got = get_config(name).param_count() / 1e9
        assert 0.75 * bn <= got <= 1.35 * bn, f"{name}: {got:.2f}B vs {bn}B"

"""The fused evaluation megakernel (L2P + M2P + P2P in one pallas_call)
and the downward P2L kernel vs the reference core sweeps, the
single-launch jaxpr property of the pallas path, and the rank-based
self-interaction exclusion (duplicated positions)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _jaxpr import count_pallas_calls
from repro.core import (FmmConfig, fmm_build, fmm_evaluate,
                        leaf_particle_index)
from repro.core import fmm as F
from repro.data.synthetic import particles
from repro.kernels import eval_fused_apply, m2p_ref, p2l_apply
from repro.kernels.common import (dense_leaf_arrays, round_up,
                                  scatter_from_leaves)
from repro.solver import FmmSolver, get_backend


def _plan(kernel="harmonic", tb=8, sw=1, nlevels=2, n=1024,
          use_p2l_m2p=True, seed=11):
    cfg = FmmConfig(n=n, nlevels=nlevels, p=8, dtype="f64", kernel=kernel,
                    strong_cap=40, weak_cap=64, use_p2l_m2p=use_p2l_m2p,
                    tile_boxes=tb, stage_width=sw)
    z, q = particles("normal", n, seed)   # clustered (adaptive) input
    return cfg, fmm_build(jnp.asarray(z), jnp.asarray(q), cfg)


def _reference_evaluation(cfg, pl, local, mult_leaf):
    """The unfused core evaluation phase: L2P (+ M2P) + P2P."""
    idx = jnp.asarray(leaf_particle_index(cfg))
    phi = F.l2p(local, pl.tree, cfg)
    if cfg.use_p2l_m2p:
        phi = F.m2p_sweep(phi, mult_leaf, pl.tree, pl.conn, cfg)
    return F.p2p_sweep(phi, pl.tree, pl.conn, cfg, idx)


TILINGS = [(1, 1), (2, 1), (8, 1),   # required sweep: tile_boxes in {1,2,8}
           (3, 1), (8, 2)]           # ragged 16 % 3 != 0; staged slots


@pytest.mark.parametrize("kernel", ["harmonic", "log"])
@pytest.mark.parametrize("tb,sw", TILINGS)
def test_eval_fused_tiled_vs_reference(kernel, tb, sw):
    cfg, pl = _plan(kernel, tb, sw)
    mult = F.upward(pl.tree, cfg)
    local = F.downward(mult, pl.tree, pl.conn, cfg)
    ref = _reference_evaluation(cfg, pl, local, mult[cfg.nlevels])
    got = eval_fused_apply(local, mult[cfg.nlevels], pl.tree, pl.conn, cfg,
                           leaf_particle_index(cfg), interpret=True)
    scale = np.abs(np.asarray(ref)).max()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-10 * scale)


@pytest.mark.parametrize("kernel", ["harmonic", "log"])
def test_eval_fused_without_m2p_region(kernel):
    """use_p2l_m2p=False drops the M2P region entirely (pure P2P+L2P)."""
    cfg, pl = _plan(kernel, use_p2l_m2p=False)
    mult = F.upward(pl.tree, cfg)
    local = F.downward(mult, pl.tree, pl.conn, cfg)
    ref = _reference_evaluation(cfg, pl, local, mult[cfg.nlevels])
    got = eval_fused_apply(local, mult[cfg.nlevels], pl.tree, pl.conn, cfg,
                           leaf_particle_index(cfg), interpret=True)
    scale = np.abs(np.asarray(ref)).max()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-10 * scale)


def test_eval_fused_tile_larger_than_nbox():
    """nlevels=1 -> 4 boxes with tile_boxes=8: one ragged tile."""
    cfg, pl = _plan("harmonic", tb=8, nlevels=1)
    mult = F.upward(pl.tree, cfg)
    local = F.downward(mult, pl.tree, pl.conn, cfg)
    ref = _reference_evaluation(cfg, pl, local, mult[cfg.nlevels])
    got = eval_fused_apply(local, mult[cfg.nlevels], pl.tree, pl.conn, cfg,
                           leaf_particle_index(cfg), interpret=True)
    scale = np.abs(np.asarray(ref)).max()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-10 * scale)


# ---------------------------------------------------------------------------
# P2L kernel vs the reference scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", ["harmonic", "log"])
@pytest.mark.parametrize("tb,sw", [(8, 1), (3, 2)])
def test_p2l_kernel_vs_sweep(kernel, tb, sw):
    cfg, pl = _plan(kernel, tb, sw, seed=3)
    idx = leaf_particle_index(cfg)
    rho = F.effective_radii(pl.tree, cfg)[cfg.nlevels]
    base = jnp.zeros((cfg.nboxes, cfg.p + 1), cfg.complex_dtype)
    ref = F.p2l_sweep(base, pl.tree, pl.conn, cfg, jnp.asarray(idx), rho)
    got = p2l_apply(pl.tree, pl.conn, cfg, idx, rho, interpret=True)
    scale = max(np.abs(np.asarray(ref)).max(), 1e-12)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-10 * scale)


def test_m2p_ref_matches_core_sweep():
    """The dense-plane M2P oracle agrees with the core rank-order sweep."""
    cfg, pl = _plan("log", seed=5)
    mult = F.upward(pl.tree, cfg)
    idx = leaf_particle_index(cfg)
    n_pad = round_up(idx.shape[1], 128)
    zr, zi, _, _, _ = dense_leaf_arrays(pl.tree.z, pl.tree.q, idx, n_pad)
    c = pl.tree.centers[cfg.nlevels]
    rho = F.effective_radii(pl.tree, cfg)[cfg.nlevels]
    P = round_up(cfg.p + 1, 128)
    pad = P - (cfg.p + 1)
    ar = jnp.pad(jnp.real(mult[-1]), ((0, 1), (0, pad)))
    ai = jnp.pad(jnp.imag(mult[-1]), ((0, 1), (0, pad)))
    mask = pl.conn.m2p >= 0
    src = jnp.where(mask, pl.conn.m2p, 0)
    outr, outi = m2p_ref(pl.conn.m2p, zr[:-1], zi[:-1], ar, ai,
                         jnp.where(mask, jnp.real(c)[src], 0.0),
                         jnp.where(mask, jnp.imag(c)[src], 0.0),
                         jnp.where(mask, rho[src], 0.0),
                         cfg.p, kernel=cfg.kernel)
    got = scatter_from_leaves(outr + 1j * outi, idx, cfg.n)
    ref = F.m2p_sweep(jnp.zeros(cfg.n, cfg.complex_dtype), mult[-1],
                      pl.tree, pl.conn, cfg)
    scale = max(np.abs(np.asarray(ref)).max(), 1e-12)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-10 * scale)


# ---------------------------------------------------------------------------
# launch-count properties (jaxpr inspection)
# ---------------------------------------------------------------------------

def _interpreted_impls(cfg):
    impls = dict(get_backend("pallas", cfg).phase_impls(cfg))

    def eval_fused(local, leaf, tree, conn, c, idx):
        return eval_fused_apply(local, leaf, tree, conn, c, idx,
                                interpret=True)

    def p2l(tree, conn, c, idx, rho):
        return p2l_apply(tree, conn, c, idx, rho, interpret=True)

    impls["eval_fused_impl"] = eval_fused
    impls["p2l_impl"] = p2l
    return impls


def test_evaluation_phase_is_single_launch():
    """The fused evaluation phase compiles to exactly ONE pallas_call."""
    cfg, pl = _plan("harmonic")
    mult = F.upward(pl.tree, cfg)
    local = F.downward(mult, pl.tree, pl.conn, cfg)
    idx = leaf_particle_index(cfg)

    jaxpr = jax.make_jaxpr(
        lambda loc, leaf: eval_fused_apply(loc, leaf, pl.tree, pl.conn,
                                           cfg, idx, interpret=True)
    )(local, mult[cfg.nlevels])
    assert count_pallas_calls(jaxpr.jaxpr) == 1


def test_pallas_path_has_no_reference_sweeps():
    """With the default config (use_p2l_m2p=True) the whole pallas-backend
    fmm_evaluate is exactly 3 launches — fused downward M2L, downward P2L,
    fused evaluation — and zero jnp fallback scans (the m2p/p2l sweeps
    would each add a scan primitive wrapping no pallas_call)."""
    cfg, pl = _plan("harmonic")
    assert cfg.use_p2l_m2p   # the default configuration
    impls = _interpreted_impls(cfg)

    jaxpr = jax.make_jaxpr(
        lambda: fmm_evaluate(pl, cfg, **impls))()
    assert count_pallas_calls(jaxpr.jaxpr) == 3

    # without the Carrier-Greengard lists there is no P2L launch
    cfg2, pl2 = _plan("harmonic", use_p2l_m2p=False)
    jaxpr2 = jax.make_jaxpr(
        lambda: fmm_evaluate(pl2, cfg2, **_interpreted_impls(cfg2)))()
    assert count_pallas_calls(jaxpr2.jaxpr) == 2


# ---------------------------------------------------------------------------
# rank-based self-interaction exclusion (duplicated positions)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_duplicated_positions_are_not_silently_dropped(backend):
    """Two *distinct* particles at the same position must interact: their
    mutual P2P term is the kernel singularity (sum over j != i by global
    index), not a silently dropped pair. Before the rank-exclusion fix
    the twins' phi came back finite-but-wrong; everyone else's phi must
    stay finite and backend-independent."""
    n = 256
    cfg = FmmConfig(n=n, nlevels=2, p=8, dtype="f64",
                    strong_cap=40, weak_cap=64)
    z, q = particles("uniform", n, 7)
    z, q = np.array(z), np.array(q)   # copies: jnp buffers are read-only
    twins = (17, 151)
    z[twins[1]] = z[twins[0]]             # distinct particles, same spot
    phi = np.asarray(FmmSolver(cfg, backend).apply(jnp.asarray(z),
                                                   jnp.asarray(q)))
    others = np.setdiff1d(np.arange(n), twins)
    assert not np.isfinite(phi[twins[0]]) and not np.isfinite(phi[twins[1]])
    assert np.isfinite(phi[others]).all()
    # non-twin entries agree with the direct index-excluded sum to FMM
    # accuracy (the twins' doubled charge is seen by everyone else);
    # kernel convention: G(z, x) = q / (x - z)
    diff = z[None, :] - z[others][:, None]
    direct = np.where(np.abs(diff) > 0, q[None, :] / np.where(
        diff != 0, diff, 1.0), 0.0).sum(axis=1)
    scale = np.abs(direct).max()
    assert np.abs(phi[others] - direct).max() / scale < 1e-5


def test_pallas_solver_end_to_end_fused():
    """backend="pallas" (now dispatching the fused evaluation + P2L
    kernels) still matches the reference solver end to end."""
    cfg = FmmConfig(n=512, nlevels=2, p=8, dtype="f64",
                    strong_cap=40, weak_cap=64)
    z, q = particles("normal", cfg.n, 13)
    z, q = jnp.asarray(z), jnp.asarray(q)
    ref = np.asarray(FmmSolver.build(cfg, "reference").apply(z, q))
    got = np.asarray(FmmSolver.build(cfg, "pallas").apply(z, q))
    scale = np.abs(ref).max()
    assert np.abs(got - ref).max() / scale < 1e-10

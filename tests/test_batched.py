"""Batch-native Pallas serving: `apply_batched` on the pallas backend
dispatches the batch-major kernel grids (no reference downgrade).

Covers the batched-vs-stacked-single-apply parity sweep (B, G-kernel,
tilings incl. ragged), the jaxpr launch-count property (B problems still
compile to the single 3-launch evaluation pipeline, not B copies), the
batch-wide overflow guard, and a fast B > 1 smoke test that the CI jax
version matrix runs explicitly so the custom batching rules cannot rot
against either supported jax.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _jaxpr import count_pallas_calls
from repro.core import FmmConfig, fmm_build, fmm_evaluate
from repro.data.synthetic import particles
from repro.solver import FmmSolver, get_backend


def _cfg(kernel="harmonic", tb=8, sw=1, n=256, nlevels=2):
    return FmmConfig(n=n, nlevels=nlevels, p=8, dtype="f64", kernel=kernel,
                     strong_cap=40, weak_cap=64, tile_boxes=tb,
                     stage_width=sw)


def _batch(b, n, dist="normal", seed0=0):
    zs, qs = [], []
    for i in range(b):
        z, q = particles(dist, n, seed0 + i)
        zs.append(np.asarray(z))
        qs.append(np.asarray(q))
    return jnp.asarray(np.stack(zs)), jnp.asarray(np.stack(qs))


# ---------------------------------------------------------------------------
# parity: apply_batched vs stacked single-problem apply
# ---------------------------------------------------------------------------

# B sweep {1, 3, 8} x tile_boxes {1, 8} + the ragged tiling (16 leaf
# boxes, 16 % 3 != 0), paired to keep the interpret-mode runtime sane.
SWEEP = [(1, 8, 1), (3, 1, 1), (3, 3, 1), (8, 8, 1), (3, 8, 2)]


@pytest.mark.parametrize("kernel", ["harmonic", "log"])
@pytest.mark.parametrize("B,tb,sw", SWEEP)
def test_apply_batched_matches_stacked_apply(kernel, B, tb, sw):
    cfg = _cfg(kernel, tb, sw)
    solver = FmmSolver.build(cfg, "pallas")
    assert solver.dispatched["apply_batched"] == "pallas"
    zb, qb = _batch(B, cfg.n)
    got = np.asarray(solver.apply_batched(zb, qb))
    ref = np.stack([np.asarray(solver.apply(zb[i], qb[i]))
                    for i in range(B)])
    scale = np.abs(ref).max()
    np.testing.assert_allclose(got, ref, atol=1e-10 * scale)
    if B > 1:   # genuinely different problems per row
        assert np.abs(got[0] - got[1]).max() / scale > 1e-3


def test_apply_batched_smoke():
    """Fast B > 1 smoke (run explicitly by the CI jax version matrix):
    the native batched pallas dispatch stays finite and backend-tagged."""
    cfg = _cfg(n=128, nlevels=1)
    solver = FmmSolver.build(cfg, "pallas")
    zb, qb = _batch(2, cfg.n, dist="uniform")
    phi = np.asarray(solver.apply_batched(zb, qb))
    assert phi.shape == (2, cfg.n)
    assert np.isfinite(phi).all()
    assert solver.dispatched["apply_batched"] == "pallas"


# ---------------------------------------------------------------------------
# launch-count property: one batch-major launch per fused phase
# ---------------------------------------------------------------------------

def _interpreted_impls(cfg):
    from repro.kernels import eval_fused_apply, p2l_apply

    impls = dict(get_backend("pallas", cfg).phase_impls(cfg))

    def eval_fused(local, leaf, tree, conn, c, idx):
        return eval_fused_apply(local, leaf, tree, conn, c, idx,
                                interpret=True)

    def p2l(tree, conn, c, idx, rho):
        return p2l_apply(tree, conn, c, idx, rho, interpret=True)

    impls["eval_fused_impl"] = eval_fused
    impls["p2l_impl"] = p2l
    return impls


def test_batched_pipeline_is_still_three_launches():
    """B problems compile to the SAME single 3-launch evaluation
    pipeline as one problem — fused downward M2L + P2L + fused
    evaluation on batch-major grids — not B copies of it."""
    cfg = _cfg("harmonic")
    assert cfg.use_p2l_m2p
    impls = _interpreted_impls(cfg)

    def evaluate(z, q):
        return fmm_evaluate(fmm_build(z, q, cfg), cfg, **impls)

    zb, qb = _batch(4, cfg.n)
    batched = jax.make_jaxpr(jax.vmap(evaluate))(zb, qb)
    single = jax.make_jaxpr(evaluate)(zb[0], qb[0])
    assert count_pallas_calls(single.jaxpr) == 3
    assert count_pallas_calls(batched.jaxpr) == 3


def test_batched_full_core_launch_count_matches_single():
    """The full pipeline (topology classify kernel included) batches
    without multiplying launches either."""
    cfg = _cfg("harmonic")
    be = get_backend("pallas", cfg)
    impls, topo = _interpreted_impls(cfg), be.topology_impls(cfg)

    def core(z, q):
        return fmm_evaluate(fmm_build(z, q, cfg, **topo), cfg, **impls)

    zb, qb = _batch(3, cfg.n)
    n_single = count_pallas_calls(
        jax.make_jaxpr(core)(zb[0], qb[0]).jaxpr)
    n_batched = count_pallas_calls(
        jax.make_jaxpr(jax.vmap(core))(zb, qb).jaxpr)
    assert n_batched == n_single == 4   # 3 evaluation + 1 leaf classify


# ---------------------------------------------------------------------------
# batch-wide overflow guard
# ---------------------------------------------------------------------------

def test_apply_batched_checked_raises_when_any_member_overflows():
    """The overflow scalar is max-reduced across the batch: one
    overflowing member raises the same re-tune error as apply_checked,
    instead of silently returning truncated potentials for that row."""
    import dataclasses
    cfg = _cfg()
    tiny = dataclasses.replace(cfg, strong_cap=2, weak_cap=2)
    zb, qb = _batch(2, cfg.n)
    solver = FmmSolver.build(tiny, "reference")
    from repro.solver import host_health
    _, health = solver.apply_batched_with_health(zb, qb)
    assert host_health(health)["overflow"] > 0
    with pytest.raises(RuntimeError, match="overflow"):
        solver.apply_batched_checked(zb, qb)
    # ...while an in-cap batch returns the plain batched answer
    ok = FmmSolver.build(cfg, "reference")
    np.testing.assert_array_equal(
        np.asarray(ok.apply_batched_checked(zb, qb)),
        np.asarray(ok.apply_batched(zb, qb)))


def test_apply_batched_checked_validates_shapes():
    solver = FmmSolver.build(_cfg(), "reference")
    z, q = _batch(2, 256)
    with pytest.raises(ValueError):
        solver.apply_batched_checked(z[0], q[0])


# ---------------------------------------------------------------------------
# batch-major kernel entries (direct, without the solver front-end)
# ---------------------------------------------------------------------------

def test_l2p_pallas_batched_matches_per_problem_loop():
    from repro.kernels import l2p_pallas, l2p_pallas_batched
    rng = np.random.default_rng(0)
    B, nbox, P, n_pad, p = 3, 8, 128, 128, 6
    br, bi = (jnp.asarray(rng.normal(size=(B, nbox, P))) for _ in range(2))
    tr, ti = (jnp.asarray(rng.normal(size=(B, nbox, n_pad)))
              for _ in range(2))
    outr, outi = l2p_pallas_batched(br, bi, tr, ti, p=p, tile_boxes=3,
                                    interpret=True)
    for b in range(B):
        rr, ri = l2p_pallas(br[b], bi[b], tr[b], ti[b], p=p, tile_boxes=3,
                            interpret=True)
        np.testing.assert_allclose(np.asarray(outr[b]), np.asarray(rr),
                                   atol=1e-12)
        np.testing.assert_allclose(np.asarray(outi[b]), np.asarray(ri),
                                   atol=1e-12)

"""Shared jaxpr-inspection helpers for the launch-count tests."""


def count_pallas_calls(jaxpr) -> int:
    """Recursively count pallas_call eqns in a jaxpr (incl. sub-jaxprs)."""
    from jax.core import Jaxpr, ClosedJaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                if isinstance(sub, ClosedJaxpr):
                    n += count_pallas_calls(sub.jaxpr)
                elif isinstance(sub, Jaxpr):
                    n += count_pallas_calls(sub)
    return n

"""Shared jaxpr-inspection helpers for the launch/sort-count tests."""


def count_eqns(jaxpr, name: str) -> int:
    """Recursively count eqns of one primitive in a jaxpr (incl. sub-jaxprs)."""
    from jax.core import Jaxpr, ClosedJaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                if isinstance(sub, ClosedJaxpr):
                    n += count_eqns(sub.jaxpr, name)
                elif isinstance(sub, Jaxpr):
                    n += count_eqns(sub, name)
    return n


def count_pallas_calls(jaxpr) -> int:
    """Recursively count pallas_call eqns in a jaxpr (incl. sub-jaxprs)."""
    return count_eqns(jaxpr, "pallas_call")


def count_sorts(jaxpr) -> int:
    """Recursively count sort eqns in a jaxpr (incl. sub-jaxprs)."""
    return count_eqns(jaxpr, "sort")

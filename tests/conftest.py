import os
import sys

# Tests run on the single real CPU device. FMM oracle tests need f64.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", True)
